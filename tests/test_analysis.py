"""Unit tests for the PA static-analysis subsystem (repro.analysis):

  * jaxpr auditor — sub-jaxpr recursion (scan/while/cond/pjit/custom_jvp/
    shard_map), full frame-chain provenance, kernel-family attribution,
    failure-message localization of an injected multiply;
  * PA contract linter — all four rules, positive and negative;
  * compiled-HLO audit — synthetic HLO modules exercising pow2 resolution
    through broadcast chains, per-computation scoping, contraction and
    integer handling;
  * collective wire-bytes model — tuple operands, iota replica_groups,
    async -start/-done dedup, group-size-1 skip;
  * AUDIT.json schema validation (benchmarks.check_bench_schema);
  * removal of the retired repro.launch.hlo_stats shim.
"""
import re

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import (
    collective_stats,
    contract_lint,
    format_violations,
    hlo_mul_stats,
    jaxpr_mul_stats,
    leaf_family,
    site_family,
)
from repro.analysis.audit import MulSite, _out_aval


def _jx(f, *args):
    return jax.make_jaxpr(f)(*args)


X = jnp.linspace(-1.0, 1.0, 16, dtype=jnp.float32).reshape(4, 4)


# ---------------------------------------------------------------------------
# Provenance: frame chains, localization, family attribution.
# ---------------------------------------------------------------------------

def _inner_mul(a):          # the injected leak, two frames below the trace
    return a * a


def _outer(a):
    return jnp.sum(_inner_mul(a))


def test_injected_multiply_localized_to_file_line_and_family():
    stats = jaxpr_mul_stats(_jx(_outer, X))
    assert stats["tensor_total"] == 1
    (v,) = stats["violations"]
    assert v["prim"] == "mul"
    assert re.search(r"tests/test_analysis\.py:\d+$", v["site"]), v["site"]
    # full non-library chain: the helper AND its caller are both present
    assert len(v["frames"]) >= 2, v["frames"]
    assert all("test_analysis.py" in fr for fr in v["frames"][:2])
    assert v["family"] == "model-code"
    assert stats["by_family"] == {"model-code": 1}
    # the human failure message carries file:line + family (acceptance)
    msg = format_violations(stats)
    assert re.search(r"mul@tests/test_analysis\.py:\d+ \[model-code\]", msg)
    assert "from tests/test_analysis.py" in msg


def test_format_violations_clean_and_truncated():
    assert "clean" in format_violations({"violations": []})
    many = {"violations": [
        {"prim": "mul", "site": f"f.py:{i}", "family": "model-code",
         "context": [], "frames": []} for i in range(15)]}
    msg = format_violations(many, limit=10)
    assert "15 tensor-shaped" in msg and "5 more" in msg


def test_site_family_rules():
    assert site_family("src/repro/kernels/pam_optim/fused.py:10") == "pam_optim"
    assert site_family("src/repro/optim/adamw.py:5") == "pam_optim"
    assert site_family(
        "src/repro/kernels/flash_attention/ref.py:7") == "pam_attention"
    assert site_family("src/repro/models/attention.py:80") == "pam_attention"
    assert site_family("src/repro/kernels/pa_softmax/k.py:1") == "pam_attention"
    assert site_family("src/repro/kernels/pam_eltwise/k.py:1") == "pam_eltwise"
    assert site_family("src/repro/kernels/pam_matmul/k.py:1") == "pam_matmul"
    assert site_family("src/repro/kernels/pa_prims.py:33") == "pam_matmul"
    assert site_family("src/repro/core/matmul.py:12") == "pam_matmul"
    assert site_family("src/repro/models/rwkv.py:165") == "model-code"
    assert site_family("?") == "model-code"


def test_leaf_family_rules():
    assert leaf_family("['opt']['m']['layers']") == "pam_optim"
    assert leaf_family("params.layers.attn.wq") == "pam_attention"
    assert leaf_family("params.layers.mlp.w_in") == "pam_matmul"
    assert leaf_family("params.final_norm.scale") == "pam_eltwise"
    assert leaf_family("params.something_else") == "pam_matmul"


def test_mulsite_describe_roundtrip():
    s = MulSite(prim="div", site="a.py:1", frames=("a.py:1", "b.py:2"),
                family="model-code", context=("scan",), shape=(4,),
                dtype="float32")
    assert s.to_dict()["frames"] == ["a.py:1", "b.py:2"]
    assert "div@a.py:1" in s.describe() and "under scan" in s.describe()


def test_out_aval_robust_to_odd_outvar_layouts():
    class _Var:
        def __init__(self, aval):
            if aval is not None:
                self.aval = aval

    class _Aval:
        def __init__(self):
            self.dtype = np.float32
            self.shape = (2,)

    class _Eqn:
        pass

    e = _Eqn()
    e.outvars, e.invars = [], [_Var(_Aval())]     # no outputs at all
    assert _out_aval(e) is not None               # falls back to invars
    e2 = _Eqn()
    e2.outvars, e2.invars = [_Var(None)], []      # outvar without aval
    assert _out_aval(e2) is None                  # never raises


# ---------------------------------------------------------------------------
# Sub-jaxpr recursion and context chains.
# ---------------------------------------------------------------------------

def test_recursion_scan_context():
    def f(x):
        def body(c, t):
            return c, t * t
        return jax.lax.scan(body, 0.0, x)

    stats = jaxpr_mul_stats(_jx(f, X))
    assert stats["tensor_total"] == 1
    assert stats["violations"][0]["context"] == ["scan"]


def test_recursion_while_and_cond():
    def f(x):
        def body(c):
            v, i = c
            v = jax.lax.cond(i < 1, lambda a: a * a, lambda a: a + 1.0, v)
            return (v, i + 1)
        v, _ = jax.lax.while_loop(lambda c: c[1] < 2, body, (x, 0))
        return v

    stats = jaxpr_mul_stats(_jx(f, X))
    assert stats["tensor_total"] >= 1
    ctx = stats["violations"][0]["context"]
    assert "while" in ctx and "cond" in ctx, ctx


def test_recursion_pjit_and_custom_jvp():
    @jax.custom_jvp
    def sq(a):
        return a * a

    @sq.defjvp
    def _sq_jvp(primals, tangents):
        (a,), (da,) = primals, tangents
        return sq(a), 2.0 * a * da

    stats = jaxpr_mul_stats(_jx(jax.jit(lambda x: jnp.sum(sq(x))), X))
    assert stats["tensor_total"] >= 1
    ctx = stats["violations"][0]["context"]
    assert any("pjit" in c for c in ctx), ctx
    assert any("custom_jvp" in c for c in ctx), ctx


def test_recursion_shard_map():
    from jax.sharding import Mesh, PartitionSpec as P
    try:
        from jax.experimental.shard_map import shard_map
    except ImportError:                      # pragma: no cover
        pytest.skip("no shard_map")
    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
    f = shard_map(lambda x: x * x, mesh=mesh, in_specs=(P(),),
                  out_specs=P(), check_rep=False)
    stats = jaxpr_mul_stats(_jx(f, X))
    assert stats["tensor_total"] == 1
    assert any("shard_map" in c for c in stats["violations"][0]["context"])


# ---------------------------------------------------------------------------
# PA contract linter.
# ---------------------------------------------------------------------------

def test_lint_non_pow2_scalar_divisor():
    out = contract_lint(_jx(lambda x: x / 3.0, X))
    assert out["counts"].get("non_pow2_scalar_divisor") == 1
    (err,) = [e for e in out["errors"]
              if e["rule"] == "non_pow2_scalar_divisor"]
    assert err["prim"] == "div" and "3.0" in err["detail"]
    # pow2 divisor and scalar-shaped results stay clean
    assert not contract_lint(_jx(lambda x: x / 4.0, X))["errors"]
    assert not contract_lint(
        _jx(lambda s: s / 3.0, jnp.float32(7.0)))["errors"]


def test_lint_wrap_risk_literal():
    big = float(2.0 ** 70)
    out = contract_lint(_jx(lambda x: x * big, X))
    assert out["counts"].get("pam_wrap_risk_literal") == 1
    assert "2^129" in out["errors"][0]["detail"] \
        or "wrap" in out["errors"][0]["detail"]
    # below the 2^64 threshold: allowed
    ok = contract_lint(_jx(lambda x: x * float(2.0 ** 40 + 1), X))
    assert not any(e["rule"] == "pam_wrap_risk_literal" for e in ok["errors"])


def test_lint_bitcast_width_mismatch():
    def bad(x):
        # f32 -> int16 splits the word across a trailing dim: a cross-width
        # bitcast can never be a PA carrier view.
        return jax.lax.bitcast_convert_type(x, jnp.int16)

    out = contract_lint(_jx(bad, X))
    assert out["counts"].get("bitcast_width_mismatch") == 1
    assert "carrier" in out["errors"][0]["detail"]

    def good(x):
        return jax.lax.bitcast_convert_type(x, jnp.int32)

    def good_bf16(x):
        # width-matched narrow-format carrier view: the bf16-native engine's
        # bread and butter, allowed since the FloatFormat refactor.
        return jax.lax.bitcast_convert_type(x.astype(jnp.bfloat16), jnp.int16)

    assert not contract_lint(_jx(good, X))["errors"]
    assert not contract_lint(_jx(good_bf16, X))["errors"]


def test_lint_scalar_mul_in_scan_warns():
    def f(x):
        def body(c, t):
            return c * np.float32(0.9), jnp.sum(t)   # non-pow2 scalar decay
        return jax.lax.scan(body, jnp.float32(1.0), x)

    out = contract_lint(_jx(f, X))
    assert out["counts"].get("scalar_mul_in_scan") == 1
    assert not out["errors"]                          # warn-only rule
    assert "O(iterations)" in out["warnings"][0]["detail"]

    def f_pow2(x):
        def body(c, t):
            return c * np.float32(0.5), jnp.sum(t)   # exponent shift: exempt
        return jax.lax.scan(body, jnp.float32(1.0), x)

    assert not contract_lint(_jx(f_pow2, X))["warnings"]


# ---------------------------------------------------------------------------
# Compiled-HLO audit.
# ---------------------------------------------------------------------------

_HLO_MODULE = """
HloModule jit_f

ENTRY %main (p0: f32[4,4]) -> f32[4,4] {
  %p0 = f32[4,4] parameter(0)
  %half = f32[] constant(1.1920929e-07)
  %bh = f32[4,4] broadcast(%half), dimensions={}
  %ok = f32[4,4] multiply(%p0, %bh)
  %c3 = f32[] constant(3)
  %b3 = f32[4,4] broadcast(%c3), dimensions={}
  ROOT %bad = f32[4,4] multiply(%ok, %b3), metadata={op_name="jit(f)/mul" source_file="/w/src/repro/models/foo.py" source_line=42}
}
"""


def test_hlo_pow2_through_broadcast_and_f32_rounding():
    """2^-23 prints as 1.1920929e-07 — pow2 only after float32 rounding; the
    non-pow2 multiply is a violation with metadata provenance."""
    s = hlo_mul_stats(_HLO_MODULE)
    assert s["pow2"] == 1
    assert s["tensor_total"] == 1
    (v,) = s["violations"]
    assert v["prim"] == "multiply"
    assert v["site"] == "src/repro/models/foo.py:42"
    assert v["family"] == "model-code"
    assert v["op_name"] == "jit(f)/mul"
    assert v["shape"] == [4, 4] and v["dtype"] == "f32"


def test_hlo_divide_dot_integer_and_scalar():
    hlo = """
ENTRY %main (p: f32[8]) -> f32[8] {
  %p = f32[8] parameter(0)
  %c4 = f32[] constant(4)
  %b4 = f32[8] broadcast(%c4), dimensions={0}
  %okdiv = f32[8] divide(%p, %b4)
  %baddiv = f32[8] divide(%b4, %p)
  %i = s32[8] multiply(%ip, %ip)
  %sc = f32[] multiply(%s, %s)
  ROOT %d = f32[] dot(%p, %p), lhs_contracting_dims={0}, rhs_contracting_dims={0}
}
"""
    s = hlo_mul_stats(hlo)
    assert s["pow2"] == 1                      # divide BY pow2 exempt
    assert s["tensor"].get("divide") == 1      # pow2 NUMERATOR is real work
    assert s["integer"] == 1                   # s32 multiply
    assert s["scalar"].get("multiply") == 1    # scalar elementwise exempt
    assert s["tensor"].get("dot") == 1         # scalar-shaped dot still counts
    assert s["tensor_total"] == 2


def test_hlo_resolution_scoped_per_computation():
    """Fusion bodies reuse names: a %c that is a pow2 constant in one
    computation must not exempt a multiply whose %c is a parameter in
    another."""
    hlo = """
%fused (param_0: f32[4]) -> f32[4] {
  %param_0 = f32[4] parameter(0)
  %c = f32[] constant(0.5)
  %bc = f32[4] broadcast(%c), dimensions={}
  ROOT %m = f32[4] multiply(%param_0, %bc)
}

ENTRY %main (p: f32[4], c: f32[4]) -> f32[4] {
  %p = f32[4] parameter(0)
  %c = f32[4] parameter(1)
  ROOT %m2 = f32[4] multiply(%p, %c)
}
"""
    s = hlo_mul_stats(hlo)
    assert s["pow2"] == 1 and s["tensor_total"] == 1


def test_hlo_rsqrt_never_exempt():
    hlo = """
ENTRY %main (p: f32[4]) -> f32[4] {
  %p = f32[4] parameter(0)
  ROOT %r = f32[4] rsqrt(%p)
}
"""
    assert hlo_mul_stats(hlo)["tensor"].get("rsqrt") == 1


# ---------------------------------------------------------------------------
# Collective wire-bytes model (satellite coverage).
# ---------------------------------------------------------------------------

def test_collective_stats_explicit_groups_and_tuple_operands():
    hlo = """
  %ar = f32[1024] all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %tup = (f32[128]{0}, f32[64]{0}) all-reduce(%a, %b), replica_groups={{0,1,2,3}}, to_apply=%add
"""
    s = collective_stats(hlo)
    assert s["all-reduce"]["count"] == 2
    # ring all-reduce: 2*(g-1)/g * bytes; 4096B and (512+256)B operands
    want = 2 * 0.75 * 4096 + 2 * 0.75 * (512 + 256)
    assert s["all-reduce"]["bytes"] == pytest.approx(want)
    assert s["total_bytes"] == pytest.approx(want)


def test_collective_stats_iota_groups_and_start_done_dedup():
    hlo = """
  %ag-start = f32[256]{0} all-gather-start(%x), replica_groups=[2,4]<=[8], dimensions={0}
  %ag-done = f32[256]{0} all-gather-done(%ag-start)
"""
    s = collective_stats(hlo)
    assert s["all-gather"]["count"] == 1          # -done half not re-counted
    assert s["all-gather"]["bytes"] == pytest.approx(0.75 * 1024)


def test_collective_stats_group_size_one_skipped():
    hlo = "  %ar = f32[64] all-reduce(%x), replica_groups={{0}}, to_apply=%a\n"
    s = collective_stats(hlo)
    assert "all-reduce" not in s and s["total_bytes"] == 0
    # collective-permute is point-to-point: counted even with no groups
    cp = "  %cp = f32[64] collective-permute(%x), source_target_pairs={{0,1}}\n"
    s2 = collective_stats(cp)
    assert s2["collective-permute"]["count"] == 1
    assert s2["collective-permute"]["bytes"] == 256


# ---------------------------------------------------------------------------
# AUDIT.json schema validation.
# ---------------------------------------------------------------------------

def _mini_absint():
    # Fresh (mutation-safe) v2 per-jaxpr-target sections.
    per_width = {
        "f32": {"mantissa_bits": 23, "rel_worst": 0.1111, "rel_mean": -0.038,
                "abs_worst": 7.2},
        "f16": {"mantissa_bits": 10, "rel_worst": 0.1131, "rel_mean": -0.038,
                "abs_worst": 7.4},
        "bf16": {"mantissa_bits": 7, "rel_worst": 0.1268, "rel_mean": -0.038,
                 "abs_worst": 8.1},
    }
    return {
        "range_safety": {"verdict": "safe", "pam_sites": 4, "padiv_sites": 1,
                         "wrap": 0, "overflow": 0, "denormal": 0,
                         "opaque_eqns": 0, "notes": [], "worst_sites": []},
        "error_certificates": {"per_width": per_width, "saturated": False,
                               "n_eqns": 100},
    }


def _mini_audit_report():
    from benchmarks.check_bench_schema import (_AUDIT_FAMILIES,
                                               audit_fingerprints)
    targets = {}
    for fam in _AUDIT_FAMILIES:
        for mode in ("approx", "full"):
            targets[f"{fam}/{mode}/train"] = {
                "kind": "jaxpr", "tensor_total": 0,
                "contract": {"errors": 0, "warnings": 0}, "pow2": 3,
                **_mini_absint()}
    targets["shard_map/train_dp"] = {
        "kind": "shard_map", "tensor_total": 0,
        "contract": {"errors": 0, "warnings": 0}, "pow2": 3,
        "collective_count": 14}
    targets["decoder/full/train@hlo"] = {
        "kind": "hlo", "tensor_total": 0,
        "contract": {"errors": 0, "warnings": 0}, "pow2": 3}
    for kind in ("train", "decode"):
        targets[f"decoder/full_bf16/{kind}"] = {
            "kind": "jaxpr", "tensor_total": 0,
            "contract": {"errors": 0, "warnings": 0}, "pow2": 3,
            "absint_twin": "f32",
            "bf16_native": {"within_certificate": True,
                            "ops": {"pam": {"measured_rel_worst": 0.11,
                                            "static_rel_bound": 0.1268}}},
            **_mini_absint()}
    return {"kind": "audit", "schema_version": 2,
            "generated_utc": "2026-08-08T00:00:00Z", "backend": "cpu",
            "device_count": 4, "families": list(_AUDIT_FAMILIES),
            "fingerprints": audit_fingerprints(),
            "declared_ranges": {"float_range": (-256.0, 256.0),
                                "float_mlo": 2.0 ** -24,
                                "activation_ceiling": 2.0 ** 32},
            "targets": targets,
            "totals": {"targets": len(targets), "tensor_total": 0,
                       "contract_errors": 0, "pow2": 3 * len(targets),
                       "pam_sites": 4 * 2 * len(_AUDIT_FAMILIES), "wrap": 0,
                       "violating_targets": []}}


def test_audit_schema_accepts_clean_report():
    from benchmarks.check_bench_schema import validate_audit_report
    assert validate_audit_report(_mini_audit_report()) == []


@pytest.mark.parametrize("mutate,needle", [
    (lambda r: r["targets"]["rwkv/full/train"].update(
        tensor_total=2, tensor_sites=["mul@core/nn.py:152"]), "regressed"),
    (lambda r: r["targets"].pop("hybrid/approx/train"), "missing coverage"),
    (lambda r: r["targets"].pop("shard_map/train_dp"),
     "no shard_map multi-device target"),
    (lambda r: r["targets"]["shard_map/train_dp"].update(collective_count=0),
     "vacuous"),
    (lambda r: r["targets"].pop("decoder/full/train@hlo"),
     "no compiled-HLO-verified target"),
    (lambda r: r["targets"].pop("decoder/full_bf16/decode"),
     "bf16-native engines"),
    (lambda r: r["targets"]["decoder/full_bf16/train"].pop("bf16_native"),
     "measured-error block"),
    (lambda r: r["targets"]["decoder/full_bf16/train"]["bf16_native"]
     .update(within_certificate=False), "exceeds"),
    (lambda r: r["targets"]["decoder/full/train"]["contract"].update(
        errors=1), "PA-contract errors"),
    (lambda r: r["totals"].update(tensor_total=5), "!= sum over targets"),
    (lambda r: r["fingerprints"].pop("analysis"), "fingerprints missing"),
    (lambda r: r.update(schema_version=1), "schema_version"),
    (lambda r: r.pop("declared_ranges"), "declared_ranges"),
    (lambda r: r["targets"]["rwkv/approx/train"].pop("range_safety"),
     "missing 'range_safety'"),
    (lambda r: r["targets"]["decoder/full/train"]["range_safety"].update(
        wrap=2, verdict="wrap"), "PAM-wrap"),
    (lambda r: r["targets"]["hybrid/full/train"]["range_safety"].update(
        pam_sites=0), "went blind"),
    (lambda r: r["targets"]["decoder/full/train"]["error_certificates"]
     ["per_width"]["bf16"].update(rel_worst=0.01), "not monotone"),
    (lambda r: r["targets"]["encdec/full/train"]["error_certificates"]
     ["per_width"]["f16"].update(rel_worst=float("inf")),
     "finite and >= 0"),
    (lambda r: r["targets"]["vision_lm/full/train"].pop(
        "error_certificates"), "missing 'error_certificates'"),
])
def test_audit_schema_rejects_mutations(mutate, needle):
    from benchmarks.check_bench_schema import validate_audit_report
    rep = _mini_audit_report()
    mutate(rep)
    errs = validate_audit_report(rep)
    assert errs and any(needle in e for e in errs), (needle, errs)


def test_audit_file_staleness_detected(tmp_path):
    import json
    from benchmarks.check_bench_schema import validate_audit_file
    rep = _mini_audit_report()
    rep["fingerprints"]["analysis"] = "0" * 16
    p = tmp_path / "AUDIT.json"
    p.write_text(json.dumps(rep))
    errs = validate_audit_file(str(p))
    assert any("stale" in e and "make audit" in e for e in errs), errs


# ---------------------------------------------------------------------------
# Shim removal (the launch/hlo_stats deprecation shim shipped its
# DeprecationWarning for one PR and is now gone).
# ---------------------------------------------------------------------------

def test_launch_hlo_stats_shim_removed():
    import importlib
    import pytest as _pytest
    with _pytest.raises(ImportError):
        importlib.import_module("repro.launch.hlo_stats")
