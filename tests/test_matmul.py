"""PA matmul front-end: value, gradients, modes."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import PAConfig, pa_matmul, pam_value
from repro.core.matmul import _pam_matmul_value, _swap


def oracle(a, b):
    return np.asarray(jnp.sum(
        pam_value(jnp.asarray(a)[..., :, :, None],
                  jnp.asarray(b)[..., None, :, :]), axis=-2))


@pytest.mark.parametrize("shape", [
    ((4, 8), (8, 4)), ((1, 1), (1, 1)), ((3, 5000), (5000, 2)),
    ((2, 3, 9, 17), (17, 7)), ((2, 1, 4, 6), (2, 5, 6, 3)),
])
def test_value_matches_oracle(rng, shape):
    sa, sb = shape
    a = rng.standard_normal(sa).astype(np.float32)
    b = rng.standard_normal(sb).astype(np.float32)
    got = np.asarray(_pam_matmul_value(jnp.asarray(a), jnp.asarray(b)))
    want = oracle(np.broadcast_to(a, np.broadcast_shapes(sa[:-2], sb[:-2]) + sa[-2:]),
                  np.broadcast_to(b, np.broadcast_shapes(sa[:-2], sb[:-2]) + sb[-2:]))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_error_vs_true_matmul_bounded(rng):
    a = rng.standard_normal((32, 64)).astype(np.float32)
    b = rng.standard_normal((64, 16)).astype(np.float32)
    pa = PAConfig(mode="matmul")
    got = np.asarray(pa_matmul(jnp.asarray(a), jnp.asarray(b), pa))
    # each scalar product has <= 11.1% magnitude error; the sum keeps the
    # same one-sided bound in terms of the absolute-value sum
    bound = np.abs(a) @ np.abs(b) / 9 + 1e-5
    assert (np.abs(got - a @ b) <= bound).all()


def test_approx_grads_are_pam_matmuls(rng):
    a = rng.standard_normal((5, 7)).astype(np.float32)
    b = rng.standard_normal((7, 3)).astype(np.float32)
    pa = PAConfig(mode="matmul", deriv="approx")
    da, db = jax.grad(lambda x, y: jnp.sum(pa_matmul(x, y, pa)),
                      argnums=(0, 1))(jnp.asarray(a), jnp.asarray(b))
    ones = jnp.ones((5, 3), jnp.float32)
    np.testing.assert_array_equal(np.asarray(da),
                                  np.asarray(_pam_matmul_value(ones, _swap(jnp.asarray(b)))))
    np.testing.assert_array_equal(np.asarray(db),
                                  np.asarray(_pam_matmul_value(_swap(jnp.asarray(a)), ones)))


def test_exact_grads_finite_and_correct_scalar(rng):
    pa = PAConfig(mode="matmul", deriv="exact")
    aa, bb = jnp.float32([[1.5]]), jnp.float32([[3.0]])
    da = jax.grad(lambda x: pa_matmul(x, bb, pa)[0, 0])(aa)
    db = jax.grad(lambda y: pa_matmul(aa, y, pa)[0, 0])(bb)
    assert float(da[0, 0]) == 4.0     # 2^(E_b + carry) = 2^(1+1)
    assert float(db[0, 0]) == 2.0     # 2^(E_a + carry) = 2^(0+1)
    a = rng.standard_normal((6, 33)).astype(np.float32)
    b = rng.standard_normal((33, 5)).astype(np.float32)
    ga, gb = jax.grad(lambda x, y: jnp.sum(pa_matmul(x, y, pa)),
                      argnums=(0, 1))(jnp.asarray(a), jnp.asarray(b))
    assert bool(jnp.isfinite(ga).all() and jnp.isfinite(gb).all())


def test_mantissa_bits_path(rng):
    a = rng.standard_normal((8, 16)).astype(np.float32)
    b = rng.standard_normal((16, 4)).astype(np.float32)
    full = pa_matmul(jnp.asarray(a), jnp.asarray(b), PAConfig(mode="matmul"))
    m4 = pa_matmul(jnp.asarray(a), jnp.asarray(b),
                   PAConfig(mode="matmul", mantissa_bits=4))
    m23 = pa_matmul(jnp.asarray(a), jnp.asarray(b),
                    PAConfig(mode="matmul", mantissa_bits=23))
    np.testing.assert_array_equal(np.asarray(full), np.asarray(m23))
    assert not np.array_equal(np.asarray(full), np.asarray(m4))
    np.testing.assert_allclose(np.asarray(m4), np.asarray(full), atol=0.5)


def test_hw_mode_is_standard_dot(rng):
    a = rng.standard_normal((8, 16)).astype(np.float32)
    b = rng.standard_normal((16, 4)).astype(np.float32)
    hw = pa_matmul(jnp.asarray(a), jnp.asarray(b),
                   PAConfig(mode="full", impl="hw"))
    np.testing.assert_allclose(np.asarray(hw), a @ b, rtol=1e-6)


def test_off_mode(rng):
    a = rng.standard_normal((8, 16)).astype(np.float32)
    b = rng.standard_normal((16, 4)).astype(np.float32)
    off = pa_matmul(jnp.asarray(a), jnp.asarray(b), PAConfig(mode="off"))
    np.testing.assert_allclose(np.asarray(off), a @ b, rtol=1e-6)
