"""FloatFormat engine-family tests (DESIGN.md §11).

Four pillars of the format refactor:

  1. Frozen bit layouts — every derived constant of FLOAT32 / BFLOAT16 /
     FLOAT16 pinned to hand-computed literals, so a change to the generic
     derivation in ``core/floatbits.py`` cannot silently move a mask.
  2. f32 bit-identity pre/post — ``get_prims("f32")`` must BE the seed
     module functions, and the generic ``_build_prims`` machinery must
     reproduce those seed bits exactly on adversarial operands (including
     the int32-wrap overflow edge), per kernel family via the K=1 /
     per-product routes that eliminate accumulation order.
  3. bf16-native semantics — denormal flush, saturation clamp (the int16
     analogue of the f32 2^129 wrap), signed zeros, and the measured
     error of the live int16-carrier engines sitting inside the static
     absint certificate (ISSUE acceptance, also re-checked by `make audit`).
  4. Format discipline — mixed f32/bf16 operands are a TypeError, never a
     silent promotion; the L-Mul engine stays inside its analytic
     [-161/2209, +1/16] band in both carriers.
"""
import importlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import PAConfig, floatbits as fb
from repro.core.matmul import pa_matmul
from repro.kernels import pa_prims as pp
from repro.kernels.pa_prims import _build_prims, get_prims

pam = importlib.import_module("repro.core.pam")


def _bits(x):
    fmt = fb.format_for_dtype(jnp.asarray(x).dtype)
    return np.asarray(jax.lax.bitcast_convert_type(jnp.asarray(x), fmt.carrier))


def _log_uniform(rng, n, e_lo, e_hi, dtype):
    mag = np.exp2(rng.uniform(e_lo, e_hi, n)).astype(np.float32)
    sgn = rng.choice([-1.0, 1.0], n).astype(np.float32)
    x = (sgn * mag).astype(np.float32)
    x[rng.random(n) < 0.05] = 0.0
    return jnp.asarray(x).astype(dtype)


# ---------------------------------------------------------------------------
# 1. Frozen layouts.
# ---------------------------------------------------------------------------

class TestFrozenLayouts:
    def test_f32(self):
        f = fb.FLOAT32
        assert (f.dtype, f.carrier) == (jnp.float32, jnp.int32)
        assert int(f.SIGN_MASK) == -(1 << 31)
        assert int(f.MAG_MASK) == 0x7FFFFFFF
        assert int(f.EXP_MASK) == 0x7F800000
        assert int(f.MAN_MASK) == 0x007FFFFF
        assert int(f.BIAS_SHIFTED) == 127 << 23
        assert int(f.MIN_NORM) == 1 << 23
        assert int(f.MAX_EXP_FIELD) == 254 << 23
        assert int(f.MAX_FINITE) == 0x7F7FFFFF
        assert int(f.INF_BITS) == 0x7F800000
        assert int(f.ZERO_SENTINEL) == -(1 << 30)
        assert (f.exp_bias, f.man_bits) == (127, 23)

    def test_bf16(self):
        f = fb.BFLOAT16
        assert (f.dtype, f.carrier) == (jnp.bfloat16, jnp.int16)
        assert int(f.SIGN_MASK) == -32768
        assert int(f.MAG_MASK) == 32767
        assert int(f.EXP_MASK) == 32640          # 0x7F80
        assert int(f.MAN_MASK) == 127
        assert int(f.BIAS_SHIFTED) == 16256      # 127 << 7
        assert int(f.MIN_NORM) == 128
        assert int(f.MAX_FINITE) == 32639        # 0x7F7F
        assert int(f.INF_BITS) == 32640
        assert int(f.ZERO_SENTINEL) == -16384
        assert (f.exp_bias, f.man_bits) == (127, 7)

    def test_f16(self):
        f = fb.FLOAT16
        assert (f.dtype, f.carrier) == (jnp.float16, jnp.int16)
        assert int(f.BIAS_SHIFTED) == 15 << 10
        assert int(f.MIN_NORM) == 1 << 10
        assert int(f.MAX_FINITE) == 0x7BFF
        assert int(f.EXP_MASK) == 0x7C00
        assert int(f.ZERO_SENTINEL) == -16384
        assert (f.exp_bias, f.man_bits) == (15, 10)

    def test_lmul_offsets(self):
        # l(m) = 4 for every supported format (m = 23, 7, 10 all > 4).
        assert fb.FLOAT32.LMUL_L == 4 and int(fb.FLOAT32.LMUL_OFFSET) == 1 << 19
        assert fb.BFLOAT16.LMUL_L == 4 and int(fb.BFLOAT16.LMUL_OFFSET) == 8
        assert fb.FLOAT16.LMUL_L == 4 and int(fb.FLOAT16.LMUL_OFFSET) == 1 << 6

    def test_sentinel_band_absorbs_lmul_fold(self):
        # The L-Mul fold shifts the re-bias by 2^(m-4); the zero-sentinel /
        # overflow-band disjointness proofs need that shift to stay far
        # below the 2^m-wide guard bands in BOTH carriers (the comment in
        # pa_prims._build_prims points here).
        for f in (fb.FLOAT32, fb.BFLOAT16, fb.FLOAT16):
            fold = int(f.BIAS_SHIFTED) - int(f.LMUL_OFFSET)
            assert 0 < fold < int(f.BIAS_SHIFTED)
            # sentinel + (mag - fold) always lands in the flush band
            # [carrier_min, MIN_NORM) — flushed, never wrapped — for any
            # in-range partner magnitude, with either fold constant.
            assert int(f.ZERO_SENTINEL) + int(f.MAX_FINITE) - fold \
                < int(f.MIN_NORM)
            assert int(f.ZERO_SENTINEL) - fold >= -(1 << (f.width - 1))


# ---------------------------------------------------------------------------
# 2. f32 bit-identity pre/post refactor.
# ---------------------------------------------------------------------------

class TestF32BitIdentity:
    def test_f32_prims_are_the_seed_functions(self):
        p = get_prims("f32", lmul=False)
        assert p.pam is pp._pam
        assert p.padiv is pp._padiv
        assert p.paexp2 is pp._paexp2
        assert p.palog2 is pp._palog2
        assert p.prep_tiles is pp._prep_tiles
        assert p.grouped_pam_sum is pp._grouped_pam_sum
        assert p.pam_dot is pp._pam_dot

    def test_generic_builder_reproduces_seed_bits(self, rng):
        """_build_prims(FLOAT32) — the formula the bf16/f16/L-Mul engines
        come from — must match the seed's literal-constant helpers bit for
        bit, including underflow-flush and the int32-wrap overflow edge."""
        gen = _build_prims(fb.FLOAT32, lmul=False)
        a = _log_uniform(rng, 4096, -140.0, 130.0, jnp.float32)
        b = _log_uniform(rng, 4096, -140.0, 130.0, jnp.float32)
        np.testing.assert_array_equal(_bits(gen.pam(a, b)),
                                      _bits(pp._pam(a, b)))
        bnz = jnp.where(b == 0.0, jnp.float32(1.0), b)
        np.testing.assert_array_equal(_bits(gen.padiv(a, bnz)),
                                      _bits(pp._padiv(a, bnz)))
        e = jnp.asarray(rng.uniform(-160.0, 160.0, 4096).astype(np.float32))
        np.testing.assert_array_equal(_bits(gen.paexp2(e)),
                                      _bits(pp._paexp2(e)))
        pos = jnp.abs(jnp.where(a == 0.0, jnp.float32(1.0), a))
        np.testing.assert_array_equal(_bits(gen.palog2(pos)),
                                      _bits(pp._palog2(pos)))

    def test_generic_tile_product_reproduces_seed_bits(self, rng):
        gen = _build_prims(fb.FLOAT32, lmul=False)
        a = _log_uniform(rng, 16 * 24, -10.0, 10.0, jnp.float32).reshape(16, 24)
        b = _log_uniform(rng, 24 * 8, -10.0, 10.0, jnp.float32).reshape(24, 8)
        np.testing.assert_array_equal(_bits(gen.pam_dot(a, b, 4)),
                                      _bits(pp._pam_dot(a, b, 4)))

    def test_matmul_family_k1_products_bit_exact(self, rng):
        """K=1 eliminates accumulation: every pam_matmul product must be
        bit-identical to the seed value-level PAM forward."""
        from repro.kernels.pam_matmul import pam_matmul
        a = _log_uniform(rng, 16, -6.0, 6.0, jnp.float32).reshape(16, 1)
        b = _log_uniform(rng, 8, -6.0, 6.0, jnp.float32).reshape(1, 8)
        got = pam_matmul(a, b, bm=8, bn=8, bk=1)
        want = pam.pam_value(a, b)
        np.testing.assert_array_equal(_bits(got), _bits(want))

    def test_attention_family_k1_scores_bit_exact(self, rng):
        """The attention family's score core IS ``pam_dot`` (pam_kernel
        resolves it through get_prims); at contraction length 1 every f32
        score must be bit-identical to the seed PAM forward. Engine-level,
        pallas and jnp agree to f32 sum order on the fused output."""
        from repro.kernels.flash_attention import pam_flash_attention
        a = _log_uniform(rng, 17, -4.0, 4.0, jnp.float32).reshape(17, 1)
        b = _log_uniform(rng, 13, -4.0, 4.0, jnp.float32).reshape(1, 13)
        np.testing.assert_array_equal(_bits(pp._pam_dot(a, b, 16)),
                                      _bits(pam.pam_value(a, b)))
        B, S, H, Dh = 1, 4, 2, 4
        q = _log_uniform(rng, B * S * H * Dh, -2.0, 2.0,
                         jnp.float32).reshape(B, S, H, Dh)
        k = _log_uniform(rng, B * S * H * Dh, -2.0, 2.0,
                         jnp.float32).reshape(B, S, H, Dh)
        v = _log_uniform(rng, B * S * H * Dh, -2.0, 2.0,
                         jnp.float32).reshape(B, S, H, Dh)
        pos = jnp.arange(S)
        o_pl = pam_flash_attention(q, k, v, pos, pos, impl="pallas",
                                   bq=4, bk=4, g=2)
        o_jn = pam_flash_attention(q, k, v, pos, pos, impl="jnp",
                                   bq=4, bk=4, g=2)
        assert o_pl.dtype == o_jn.dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_jn),
                                   rtol=1e-5, atol=1e-6)

    def test_optim_family_engines_bit_equal(self, rng):
        from repro.kernels.pam_optim.ops import pa_adamw_update
        p = {"w": _log_uniform(rng, 64, -4.0, 2.0, jnp.float32)}
        g = {"w": _log_uniform(rng, 64, -6.0, 0.0, jnp.float32)}
        m = {"w": jnp.zeros(64, jnp.float32)}
        v = {"w": jnp.zeros(64, jnp.float32)}
        kw = dict(b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1)
        outs = {}
        for impl in ("jnp", "pallas"):
            outs[impl] = pa_adamw_update(p, g, m, v, 1, 1e-3, None,
                                         impl=impl, fmt="f32", **kw)
        for a, b in zip(jax.tree_util.tree_leaves(outs["jnp"]),
                        jax.tree_util.tree_leaves(outs["pallas"])):
            np.testing.assert_array_equal(_bits(a), _bits(b))

    def test_softmax_family_f32_path_unchanged(self, rng):
        """f32 softmax inputs must produce f32 outputs through the seed
        (int32-carrier) route — and the generic-builder f32 prims compose
        to the same bits as the kernel's helpers."""
        from repro.kernels.pa_softmax import pa_softmax
        x = _log_uniform(rng, 4 * 32, -3.0, 3.0, jnp.float32).reshape(4, 32)
        y = pa_softmax(x)
        assert y.dtype == jnp.float32
        rows = np.asarray(jnp.sum(y, axis=-1))
        np.testing.assert_allclose(rows, np.ones_like(rows), rtol=0.2)


# ---------------------------------------------------------------------------
# 3. bf16-native semantics (absint agreement).
# ---------------------------------------------------------------------------

class TestBf16Semantics:
    def test_underflow_flushes_to_signed_zero(self):
        a = jnp.asarray(2.0 ** -100, jnp.bfloat16)
        b = jnp.asarray(-(2.0 ** -60), jnp.bfloat16)
        out = pam.pam_value(a, b)
        assert out.dtype == jnp.bfloat16
        assert float(out) == 0.0
        assert int(_bits(out)) == int(fb.BFLOAT16.SIGN_MASK)  # -0.0

    def test_denormal_input_is_zero_for_the_engines(self):
        # Exponent-field zero test (int16 carrier): a bf16 denormal operand
        # behaves as exact zero, matching the flush-to-zero absint domain.
        denorm = fb.floats(jnp.asarray(64, jnp.int16), fb.BFLOAT16)  # 2^-127
        assert float(denorm) != 0.0                 # it IS a denormal value
        p = get_prims("bf16").pam(denorm, jnp.asarray(3.0, jnp.bfloat16))
        assert float(p) == 0.0

    def test_overflow_saturates_to_max_finite(self):
        # exponent sum 240 > 254-field ceiling: clamp, not inf, not wrap.
        a = jnp.asarray(2.0 ** 120, jnp.bfloat16)
        out = pam.pam_value(a, a)
        assert int(_bits(out)) == int(fb.BFLOAT16.MAX_FINITE)
        neg = pam.pam_value(-a, a)
        assert int(_bits(neg)) == np.int16(
            fb.BFLOAT16.SIGN_MASK | fb.BFLOAT16.MAX_FINITE)

    def test_int16_wrap_edge_saturates(self):
        """The int16 analogue of the f32 2^129 wrap (DESIGN.md §11): two
        max-finite magnitudes overflow the carrier add; the disjoint
        negative-range test must classify it as overflow -> MAX_FINITE."""
        top = fb.floats(jnp.asarray(int(fb.BFLOAT16.MAX_FINITE), jnp.int16),
                        fb.BFLOAT16)
        out = get_prims("bf16").pam(top, top)
        assert int(_bits(out)) == int(fb.BFLOAT16.MAX_FINITE)

    def test_bf16_relative_error_inside_certificate_band(self, rng):
        from repro.analysis.domains import EPS_PAM_WORST, quant_eps
        a = _log_uniform(rng, 8192, -20.0, 20.0, jnp.bfloat16)
        b = _log_uniform(rng, 8192, -20.0, 20.0, jnp.bfloat16)
        got = np.asarray(pam.pam_value(a, b), np.float64)
        true = np.asarray(a, np.float64) * np.asarray(b, np.float64)
        nz = true != 0.0
        rel = got[nz] / true[nz] - 1.0
        qe = quant_eps(fb.BFLOAT16.man_bits)
        assert rel.max() <= qe + 1e-9
        assert rel.min() >= -EPS_PAM_WORST - qe - 1e-9

    def test_measured_bf16_error_within_static_certificate(self):
        # ISSUE acceptance, pinned in tier-1 (the audit re-checks the same
        # block when it regenerates AUDIT.json).
        from repro.launch.audit import bf16_measured_block
        block = bf16_measured_block()
        assert block["within_certificate"] is True
        for op, rec in block["ops"].items():
            assert rec["measured_rel_worst"] <= rec["static_rel_worst"], op

    def test_bf16_matmul_reduced_operand_bytes(self, rng):
        # The bf16 kernels see half-width operands end to end: output dtype
        # stays bf16 (no silent f32 upcast of the result).
        from repro.kernels.pam_matmul import pam_matmul
        a = _log_uniform(rng, 16 * 32, -3.0, 3.0, jnp.bfloat16).reshape(16, 32)
        b = _log_uniform(rng, 32 * 8, -3.0, 3.0, jnp.bfloat16).reshape(32, 8)
        out = pam_matmul(a, b, bm=8, bn=8, bk=16)
        assert out.dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# 4. Format discipline + the L-Mul band.
# ---------------------------------------------------------------------------

class TestFormatDiscipline:
    def test_mixed_formats_raise(self, rng):
        a32 = jnp.asarray(rng.standard_normal(8).astype(np.float32))
        a16 = a32.astype(jnp.bfloat16)
        with pytest.raises(TypeError, match="one float format"):
            pam.pam_value(a32, a16)
        with pytest.raises(TypeError, match="one float format"):
            pam.pam(a16, a32)

    def test_scalars_follow_the_array_operand(self):
        # np.float32 constants (core/nn.py style) carry no format vote.
        x = jnp.asarray([1.5, 2.5], jnp.bfloat16)
        out = pam.pam_value(x, np.float32(2.0))
        assert out.dtype == jnp.bfloat16

    @pytest.mark.parametrize("fmt_name", ["f32", "bf16"])
    def test_lmul_error_band(self, rng, fmt_name):
        from repro.analysis.domains import quant_eps
        fmt = fb.FORMATS[fmt_name]
        a = _log_uniform(rng, 8192, -12.0, 12.0, fmt.dtype)
        b = _log_uniform(rng, 8192, -12.0, 12.0, fmt.dtype)
        got = np.asarray(pam.lmul_value(a, b), np.float64)
        true = np.asarray(a, np.float64) * np.asarray(b, np.float64)
        nz = true != 0.0
        rel = got[nz] / true[nz] - 1.0
        qe = quant_eps(fmt.man_bits)
        assert rel.max() <= pp.LMUL_REL_PLUS + qe + 1e-9
        assert rel.min() >= -pp.LMUL_REL_WORST - qe - 1e-9

    def test_lmul_engine_through_matmul(self, rng):
        cfg = PAConfig(mode="full", impl="lmul", deriv="approx",
                       loss_deriv="approx")
        a = _log_uniform(rng, 8 * 16, -4.0, 4.0, jnp.float32).reshape(8, 16)
        b = _log_uniform(rng, 16 * 4, -4.0, 4.0, jnp.float32).reshape(16, 4)
        got = np.asarray(pa_matmul(a, b, cfg), np.float64)
        a64, b64 = np.asarray(a, np.float64), np.asarray(b, np.float64)
        true = a64 @ b64
        # Per-product relative error is banded, so the accumulated error is
        # bounded by band * sum(|products|) — NOT by band * |sum| (signed
        # cancellation can make the naive relative error arbitrarily large).
        band = max(pp.LMUL_REL_WORST, pp.LMUL_REL_PLUS) + 2.0 ** -22
        bound = band * (np.abs(a64) @ np.abs(b64))
        assert np.all(np.abs(got - true) <= bound + 1e-9)
