"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.pam_matmul import pam_matmul, pam_matmul_ref
from repro.kernels.pam_eltwise import ops as elt
from repro.kernels.pam_eltwise.ref import REFS
from repro.kernels.pa_softmax import pa_softmax, pa_softmax_ref


class TestPamMatmulKernel:
    @pytest.mark.parametrize("mkn", [
        (4, 7, 5), (128, 128, 128), (130, 257, 65), (1, 1000, 3),
        (16, 16, 16), (8, 513, 8),
    ])
    def test_shape_sweep_vs_oracle(self, rng, mkn):
        m, k, n = mkn
        a = rng.standard_normal((m, k)).astype(np.float32)
        b = rng.standard_normal((k, n)).astype(np.float32)
        got = np.asarray(pam_matmul(jnp.asarray(a), jnp.asarray(b),
                                    bm=32, bn=32, bk=64))
        ref = np.asarray(pam_matmul_ref(a, b))
        # products are bit-identical; only f32 accumulation ORDER differs
        # between the K-blocked kernel and the single-sum oracle
        np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("dtype", [np.float32, np.float16])
    def test_dtype_inputs(self, rng, dtype):
        a = rng.standard_normal((16, 32)).astype(dtype)
        b = rng.standard_normal((32, 8)).astype(dtype)
        got = np.asarray(pam_matmul(jnp.asarray(a), jnp.asarray(b),
                                    bm=8, bn=8, bk=16))
        ref = np.asarray(pam_matmul_ref(np.float32(a), np.float32(b)))
        np.testing.assert_allclose(got, ref, rtol=2e-5, atol=2e-5)

    def test_batched(self, rng):
        a = rng.standard_normal((2, 3, 16, 24)).astype(np.float32)
        b = rng.standard_normal((2, 3, 24, 8)).astype(np.float32)
        got = np.asarray(pam_matmul(jnp.asarray(a), jnp.asarray(b),
                                    bm=8, bn=8, bk=8))
        for i in range(2):
            for j in range(3):
                ref = np.asarray(pam_matmul_ref(a[i, j], b[i, j]))
                np.testing.assert_allclose(got[i, j], ref, rtol=2e-5, atol=2e-5)

    def test_leading_dims_collapse(self, rng):
        a = rng.standard_normal((3, 4, 8, 16)).astype(np.float32)
        b = rng.standard_normal((16, 8)).astype(np.float32)
        got = np.asarray(pam_matmul(jnp.asarray(a), jnp.asarray(b),
                                    bm=16, bn=8, bk=16))
        assert got.shape == (3, 4, 8, 8)
        ref = np.asarray(pam_matmul_ref(a[1, 2], b))
        np.testing.assert_allclose(got[1, 2], ref, rtol=2e-5, atol=2e-5)

    def test_zeros_pad_exact(self):
        """Padding correctness: PAM(0, x) == 0 exactly."""
        a = np.zeros((5, 9), np.float32)
        b = np.ones((9, 3), np.float32)
        got = np.asarray(pam_matmul(jnp.asarray(a), jnp.asarray(b),
                                    bm=4, bn=4, bk=4))
        np.testing.assert_array_equal(got, 0.0)


class TestEltwiseKernels:
    @pytest.mark.parametrize("op", ["pam", "padiv"])
    def test_binary_vs_oracle(self, rng, op):
        x = (rng.standard_normal(9999) * 10 ** rng.uniform(-5, 5, 9999)).astype(np.float32)
        y = (rng.standard_normal(9999) * 10 ** rng.uniform(-5, 5, 9999)).astype(np.float32)
        got = np.asarray(getattr(elt, op)(jnp.asarray(x), jnp.asarray(y)))
        ref = np.asarray(REFS[op](jnp.asarray(x), jnp.asarray(y)))
        np.testing.assert_array_equal(got, ref)

    def test_paexp2_vs_oracle(self, rng):
        x = rng.uniform(-100, 100, 5000).astype(np.float32)
        got = np.asarray(elt.paexp2(jnp.asarray(x)))
        ref = np.asarray(REFS["paexp2"](jnp.asarray(x)))
        np.testing.assert_array_equal(got, ref)

    def test_palog2_vs_oracle(self, rng):
        x = np.abs(rng.standard_normal(5000)).astype(np.float32) + 1e-10
        got = np.asarray(elt.palog2(jnp.asarray(x)))
        ref = np.asarray(REFS["palog2"](jnp.asarray(x)))
        np.testing.assert_array_equal(got, ref)

    def test_nd_shapes(self, rng):
        x = rng.standard_normal((3, 5, 7)).astype(np.float32)
        y = rng.standard_normal((3, 5, 7)).astype(np.float32)
        got = np.asarray(elt.pam(jnp.asarray(x), jnp.asarray(y)))
        assert got.shape == (3, 5, 7)


class TestSoftmaxKernel:
    @pytest.mark.parametrize("shape", [(8, 128), (37, 129), (1, 4096), (200, 33)])
    def test_vs_oracle(self, rng, shape):
        x = rng.standard_normal(shape).astype(np.float32) * 3
        got = np.asarray(pa_softmax(jnp.asarray(x)))
        ref = np.asarray(pa_softmax_ref(jnp.asarray(x)))
        np.testing.assert_array_equal(got, ref)

    def test_long_row_fallback(self, rng):
        x = rng.standard_normal((4, 8192)).astype(np.float32)
        got = np.asarray(pa_softmax(jnp.asarray(x)))
        ref = np.asarray(pa_softmax_ref(jnp.asarray(x)))
        np.testing.assert_array_equal(got, ref)


class TestFlashAttentionKernel:
    """Flash (online-softmax) attention vs the quadratic oracle."""

    @pytest.mark.parametrize("cfg", [
        (2, 64, 32, 16, 16), (3, 100, 16, 32, 32), (1, 257, 64, 64, 64),
        (2, 128, 8, 128, 128),
    ])
    def test_shape_sweep_vs_oracle(self, rng, cfg):
        from repro.kernels.flash_attention import attention_ref
        from repro.kernels.flash_attention.kernel import flash_attention_bh
        bh, s, dh, bq, bk = cfg
        q = jnp.asarray(rng.standard_normal((bh, s, dh)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((bh, s, dh)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((bh, s, dh)), jnp.float32)
        got = np.asarray(flash_attention_bh(q, k, v, bq=bq, bk=bk,
                                            interpret=True))
        ref = np.asarray(attention_ref(q, k, v))
        np.testing.assert_allclose(got, ref, atol=2e-5)

    def test_gqa_wrapper(self, rng):
        from repro.kernels.flash_attention import flash_attention
        q = jnp.asarray(rng.standard_normal((2, 32, 8, 16)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((2, 32, 2, 16)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((2, 32, 2, 16)), jnp.float32)
        out = flash_attention(q, k, v, bq=16, bk=16)
        assert out.shape == (2, 32, 8, 16)
        assert bool(jnp.isfinite(out).all())

    @pytest.mark.parametrize("st", [(100, 100), (64, 100), (100, 64)])
    def test_noncausal_ragged_padding(self, rng, st):
        """Regression: padded key rows must be masked positionally in the
        NON-causal path too (zero-padded keys used to get exp(0-m) softmax
        weight at any T that is not a block multiple)."""
        from repro.kernels.flash_attention import attention_ref
        from repro.kernels.flash_attention.kernel import flash_attention_bh
        s, t = st
        q = jnp.asarray(rng.standard_normal((2, s, 16)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((2, t, 16)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((2, t, 16)), jnp.float32)
        got = np.asarray(flash_attention_bh(q, k, v, bq=32, bk=32,
                                            causal=False, interpret=True))
        ref = np.asarray(attention_ref(q, k, v, causal=False))
        np.testing.assert_allclose(got, ref, atol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, rng, dtype):
        from repro.kernels.flash_attention import attention_ref
        from repro.kernels.flash_attention.kernel import flash_attention_bh
        q = jnp.asarray(rng.standard_normal((2, 64, 32)), dtype)
        k = jnp.asarray(rng.standard_normal((2, 64, 32)), dtype)
        v = jnp.asarray(rng.standard_normal((2, 64, 32)), dtype)
        got = np.asarray(flash_attention_bh(q, k, v, bq=32, bk=32,
                                            interpret=True), np.float32)
        ref = np.asarray(attention_ref(q, k, v), np.float32)
        np.testing.assert_allclose(got, ref, atol=2e-2)
