"""Unit tests for the core PA ops (paper §2.2–2.3, Fig. 2)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (pam_value, padiv_value, paexp2_value, palog2_value,
                        paexp, palog, pasqrt, parecip, pam_compensated,
                        ALPHA_MEAN)
from repro.core import floatbits as fb


def arr(*xs):
    return jnp.asarray(np.array(xs, np.float32))


class TestPAM:
    def test_exact_at_powers_of_two(self, rng):
        a = jnp.asarray(2.0 ** rng.integers(-20, 20, 1000), jnp.float32)
        b = jnp.asarray(rng.standard_normal(1000), jnp.float32)
        np.testing.assert_array_equal(pam_value(a, b), a * b)
        np.testing.assert_array_equal(pam_value(b, a), a * b)

    def test_error_band(self, rng):
        """Relative error in [-1/9, 0] (paper §2.7)."""
        a = jnp.asarray(rng.standard_normal(200000) *
                        np.exp(rng.uniform(-20, 20, 200000)), jnp.float32)
        b = jnp.asarray(rng.standard_normal(200000) *
                        np.exp(rng.uniform(-20, 20, 200000)), jnp.float32)
        rel = np.asarray((pam_value(a, b) - a * b) / (a * b))
        assert rel.min() >= -1 / 9 - 1e-6
        assert rel.max() <= 1e-6

    def test_worst_case_at_half_mantissas(self):
        # 1.5 * 1.5 = 2.25 ; PAM gives 2.0 -> -1/9 error
        assert float(pam_value(arr(1.5), arr(1.5))[0]) == 2.0

    def test_signs(self):
        got = pam_value(arr(2.0, -2.0, -2.0), arr(3.0, 3.0, -3.0))
        np.testing.assert_array_equal(got, [6.0, -6.0, 6.0])

    def test_zero_and_specials(self):
        assert float(pam_value(arr(0.0), arr(5.0))[0]) == 0.0
        assert float(pam_value(arr(5.0), arr(0.0))[0]) == 0.0
        assert np.isinf(float(pam_value(arr(np.inf), arr(2.0))[0]))
        assert np.isnan(float(pam_value(arr(np.nan), arr(2.0))[0]))
        assert np.isnan(float(pam_value(arr(np.inf), arr(0.0))[0]))

    def test_underflow_flush_overflow_clamp(self):
        tiny = arr(1e-30)
        assert float(pam_value(tiny, tiny)[0]) == 0.0       # denormal flush
        huge = arr(1e30)
        assert np.isfinite(float(pam_value(huge, huge)[0]))  # clamped

    def test_compensation_reduces_bias(self, rng):
        a = jnp.asarray(np.exp(rng.uniform(-3, 3, 50000)), jnp.float32)
        b = jnp.asarray(np.exp(rng.uniform(-3, 3, 50000)), jnp.float32)
        plain = np.mean(np.asarray(pam_value(a, b)) / np.asarray(a * b))
        comp = np.mean(np.asarray(pam_compensated(a, b)) / np.asarray(a * b))
        assert abs(comp - 1.0) < abs(plain - 1.0)


class TestPADiv:
    def test_exact_at_powers_of_two(self, rng):
        b = jnp.asarray(2.0 ** rng.integers(-15, 15, 1000), jnp.float32)
        a = jnp.asarray(rng.standard_normal(1000), jnp.float32)
        np.testing.assert_allclose(padiv_value(a, b), a / b, rtol=0)

    def test_inverse_of_pam(self, rng):
        a = jnp.asarray(np.exp(rng.uniform(-5, 5, 1000)), jnp.float32)
        b = jnp.asarray(np.exp(rng.uniform(-5, 5, 1000)), jnp.float32)
        np.testing.assert_allclose(padiv_value(pam_value(a, b), b), a,
                                   rtol=1e-6)

    def test_specials(self):
        assert float(padiv_value(arr(0.0), arr(3.0))[0]) == 0.0
        assert np.isinf(float(padiv_value(arr(3.0), arr(0.0))[0]))
        assert np.isnan(float(padiv_value(arr(0.0), arr(0.0))[0]))


class TestExpLog:
    def test_paexp2_integer_points(self):
        x = arr(-3.0, -1.0, 0.0, 1.0, 5.0)
        np.testing.assert_array_equal(paexp2_value(x), 2.0 ** np.asarray(x))

    def test_paexp2_piecewise_affine_between_integers(self):
        # slope within [n, n+1) is exactly 2^n
        x = jnp.linspace(1.1, 1.9, 9)
        y = np.asarray(paexp2_value(x))
        slopes = np.diff(y) / np.diff(np.asarray(x))
        np.testing.assert_allclose(slopes, 2.0, rtol=1e-4)

    def test_palog2_exact_at_powers(self):
        x = arr(0.25, 0.5, 1.0, 2.0, 1024.0)
        np.testing.assert_array_equal(palog2_value(x),
                                      np.log2(np.asarray(x)))

    def test_roundtrip(self, rng):
        x = jnp.asarray(np.exp(rng.uniform(-10, 10, 1000)), jnp.float32)
        np.testing.assert_allclose(paexp2_value(palog2_value(x)), x, rtol=1e-6)

    def test_palog2_domain(self):
        assert np.isnan(float(palog2_value(arr(-1.0))[0]))
        assert np.isneginf(float(palog2_value(arr(0.0))[0]))

    def test_paexp2_masked_softmax_inputs(self):
        # -1e30 mask values and -inf must map to 0, not NaN
        out = paexp2_value(arr(-1e30, -np.inf, -1e4))
        np.testing.assert_array_equal(out, [0.0, 0.0, 0.0])


class TestDerived:
    def test_pasqrt(self):
        np.testing.assert_array_equal(pasqrt(arr(16.0, 64.0, 1.0)),
                                      [4.0, 8.0, 1.0])

    def test_paexp_palog_roundtrip(self, rng):
        x = jnp.asarray(np.exp(rng.uniform(-3, 3, 100)), jnp.float32)
        np.testing.assert_allclose(paexp(palog(x)), x, rtol=0.08)

    def test_parecip(self):
        np.testing.assert_allclose(parecip(arr(2.0, 4.0, 0.5)),
                                   [0.5, 0.25, 2.0], rtol=0)


class TestFloatBits:
    def test_mantissa_round_bf16(self, rng):
        x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
        r = fb.mantissa_round(x, 7)
        # representable in bfloat16 exactly
        np.testing.assert_array_equal(np.asarray(r),
                                      np.asarray(r).astype(np.dtype("bfloat16") if False else np.float32))
        rel = np.abs(np.asarray((r - x) / x))
        assert rel.max() <= 2.0 ** -8 + 1e-9   # half ulp at 7 bits

    def test_mantissa_round_idempotent(self, rng):
        x = jnp.asarray(rng.standard_normal(100), jnp.float32)
        r1 = fb.mantissa_round(x, 4)
        np.testing.assert_array_equal(fb.mantissa_round(r1, 4), r1)

    def test_pow2_mul_exact(self, rng):
        x = jnp.asarray(rng.standard_normal(100), jnp.float32)
        np.testing.assert_array_equal(fb.pow2_mul(x, 3), x * 8.0)
        np.testing.assert_array_equal(fb.pow2_mul(x, -2), x / 4.0)

    def test_is_pow2(self):
        got = fb.is_pow2(arr(1.0, 2.0, 3.0, 0.5, 0.0, -4.0))
        np.testing.assert_array_equal(got, [True, True, False, True, False, True])


class TestOverflowEdgeCases:
    """hypothesis-found int32 wraparound: huge*huge must clamp, not flush."""

    def test_pam_double_overflow_clamps(self):
        a = jnp.float32(1.766e29)
        b = jnp.float32(4.05e9)      # true product 7.2e38 > f32 max
        out = float(pam_value(arr(1.766e29), arr(4.05e9))[0])
        assert out == float(jnp.finfo(jnp.float32).max)

    def test_pam_monotone_through_overflow(self):
        b = arr(4.05e9)
        lo = float(pam_value(arr(1.0), b)[0])
        hi = float(pam_value(arr(1.766e29), b)[0])
        assert hi >= lo

    def test_padiv_overflow_clamps(self):
        # divisor must be a NORMAL float (XLA CPU flushes denormals; the
        # paper flushes them too, yielding the a/0 -> inf path instead)
        out = float(padiv_value(arr(1e38), arr(2e-38))[0])
        assert out == float(jnp.finfo(jnp.float32).max)

    def test_kernels_match_after_fix(self, rng):
        from repro.kernels.pam_eltwise import ops as elt
        x = jnp.asarray(np.array([1.766e29, 1e38, 1.0], np.float32))
        y = jnp.asarray(np.array([4.05e9, 1e12, 2.0], np.float32))
        np.testing.assert_array_equal(np.asarray(elt.pam(x, y)),
                                      np.asarray(pam_value(x, y)))
