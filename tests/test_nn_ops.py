"""PA network ops (paper §3.3): softmax, norms, activations, loss."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (PAConfig, OFF, pa_softmax, pa_logsumexp, pa_layernorm,
                        pa_rmsnorm, pa_cross_entropy, ACTIVATIONS)

FULL = PAConfig(mode="full", deriv="approx", loss_deriv="exact")


class TestSoftmax:
    def test_rows_sum_near_one(self, rng):
        x = jnp.asarray(rng.standard_normal((64, 33)), jnp.float32)
        s = pa_softmax(x, FULL)
        np.testing.assert_allclose(np.asarray(jnp.sum(s, -1)), 1.0, atol=0.1)
        assert (np.asarray(s) >= 0).all()

    def test_close_to_standard(self, rng):
        x = jnp.asarray(rng.standard_normal((16, 9)), jnp.float32)
        np.testing.assert_allclose(np.asarray(pa_softmax(x, FULL)),
                                   np.asarray(jax.nn.softmax(x)), atol=0.05)

    def test_masked(self, rng):
        x = jnp.asarray(rng.standard_normal((8, 12)), jnp.float32)
        mask = jnp.asarray(rng.random((8, 12)) > 0.4)
        s = np.asarray(pa_softmax(x, FULL, where=mask))
        assert (s[~np.asarray(mask)] == 0).all()
        assert np.isfinite(s).all()

    def test_grads_finite_both_derivs(self, rng):
        x = jnp.asarray(rng.standard_normal((4, 7)), jnp.float32)
        for d in ("approx", "exact"):
            pa = PAConfig(mode="full", deriv=d)
            g = jax.grad(lambda v: jnp.sum(pa_softmax(v, pa)[:, 0]))(x)
            assert bool(jnp.isfinite(g).all())

    def test_logsumexp(self, rng):
        x = jnp.asarray(rng.standard_normal((5, 11)) * 3, jnp.float32)
        got = np.asarray(pa_logsumexp(x, FULL))
        want = np.asarray(jax.scipy.special.logsumexp(x, axis=-1))
        np.testing.assert_allclose(got, want, atol=0.15)


class TestNorms:
    def test_layernorm_normalises(self, rng):
        x = jnp.asarray(rng.standard_normal((32, 128)) * 5 + 2, jnp.float32)
        y = np.asarray(pa_layernorm(x, None, None, FULL))
        np.testing.assert_allclose(y.mean(-1), 0.0, atol=0.05)
        np.testing.assert_allclose(y.std(-1), 1.0, atol=0.1)

    def test_layernorm_parametric(self, rng):
        x = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
        gamma = jnp.asarray(rng.standard_normal(16) + 1, jnp.float32)
        beta = jnp.asarray(rng.standard_normal(16), jnp.float32)
        got = np.asarray(pa_layernorm(x, gamma, beta, FULL))
        want = np.asarray(pa_layernorm(x, gamma, beta, OFF))
        np.testing.assert_allclose(got, want, atol=0.35)

    def test_rmsnorm(self, rng):
        x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
        got = np.asarray(pa_rmsnorm(x, None, FULL))
        want = np.asarray(pa_rmsnorm(x, None, OFF))
        # compound PAM error (square, mean, pasqrt, padiv) stays ~<12% rel
        np.testing.assert_allclose(got, want, atol=0.12 * np.abs(want).max() + 0.05)

    def test_grads_finite(self, rng):
        x = jnp.asarray(rng.standard_normal((4, 8)), jnp.float32)
        g = jax.grad(lambda v: jnp.sum(pa_layernorm(v, None, None, FULL)))(x)
        assert bool(jnp.isfinite(g).all())


class TestActivations:
    @pytest.mark.parametrize("name", list(ACTIVATIONS))
    def test_close_to_standard_and_differentiable(self, rng, name):
        x = jnp.asarray(rng.standard_normal(256) * 2, jnp.float32)
        act = ACTIVATIONS[name]
        got, want = np.asarray(act(x, FULL)), np.asarray(act(x, OFF))
        np.testing.assert_allclose(got, want, atol=0.25)
        g = jax.grad(lambda v: jnp.sum(act(v, FULL)))(x)
        assert bool(jnp.isfinite(g).all())


class TestCrossEntropy:
    def test_close_to_standard(self, rng):
        logits = jnp.asarray(rng.standard_normal((32, 50)) * 2, jnp.float32)
        labels = jnp.asarray(rng.integers(0, 50, 32))
        for ls in (0.0, 0.1):
            got = float(pa_cross_entropy(logits, labels, FULL, label_smoothing=ls))
            want = float(pa_cross_entropy(logits, labels, OFF, label_smoothing=ls))
            assert abs(got - want) < 0.15 * max(1.0, want)

    def test_masked(self, rng):
        logits = jnp.asarray(rng.standard_normal((4, 8, 16)), jnp.float32)
        labels = jnp.asarray(rng.integers(0, 16, (4, 8)))
        mask = jnp.asarray(rng.random((4, 8)) > 0.3)
        got = float(pa_cross_entropy(logits, labels, FULL, where=mask))
        assert np.isfinite(got)

    def test_grads_both_derivs(self, rng):
        logits = jnp.asarray(rng.standard_normal((8, 12)), jnp.float32)
        labels = jnp.asarray(rng.integers(0, 12, 8))
        for ld in ("exact", "approx"):
            pa = PAConfig(mode="full", loss_deriv=ld)
            g = jax.grad(lambda l: pa_cross_entropy(l, labels, pa,
                                                    label_smoothing=0.1))(logits)
            assert bool(jnp.isfinite(g).all())
            # gradient should point the right way: increasing the target
            # logit decreases the loss
            tgt = np.asarray(g)[np.arange(8), np.asarray(labels)]
            assert (tgt < 0).all()
