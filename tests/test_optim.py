"""Optimizer: standard vs fully-PA AdamW (paper §2.6)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import PAConfig
from repro.optim import OptConfig, init_opt_state, adamw_update, lr_at


def toy_params(rng):
    return {"w": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal(8), jnp.float32)}


def toy_grads(rng):
    return {"w": jnp.asarray(rng.standard_normal((8, 8)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal(8), jnp.float32)}


def test_standard_update_moves_against_gradient(rng):
    cfg = OptConfig(peak_lr=1e-2, warmup_steps=1, total_steps=10,
                    weight_decay=0.0, grad_clip=0.0)
    p = toy_params(rng)
    g = jax.tree.map(jnp.ones_like, p)
    st = init_opt_state(p, cfg)
    p2, st2, _ = adamw_update(p, g, st, cfg)
    assert (np.asarray(p2["w"]) < np.asarray(p["w"])).all()
    assert int(st2["step"]) == 1


def test_pa_update_close_to_standard(rng):
    cfg = OptConfig(peak_lr=1e-2, warmup_steps=1, total_steps=10)
    pa = PAConfig(mode="full")
    p = toy_params(rng)
    st_s = init_opt_state(p, cfg)
    st_p = init_opt_state(p, cfg)
    ps, pp = p, p
    for i in range(5):
        g = toy_grads(np.random.default_rng(i))
        ps, st_s, _ = adamw_update(ps, g, st_s, cfg)
        pp, st_p, _ = adamw_update(pp, g, st_p, cfg, pa=pa)
    dw = np.abs(np.asarray(ps["w"]) - np.asarray(pp["w"]))
    step_mag = np.abs(np.asarray(ps["w"]) - np.asarray(p["w"])).mean()
    assert dw.mean() < 0.5 * step_mag   # PA tracks the standard trajectory


def test_pa_update_multiplication_free_semantics(rng):
    """PA optimizer must not NaN/blow up on extreme gradients."""
    cfg = OptConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10)
    pa = PAConfig(mode="full")
    p = toy_params(rng)
    g = {"w": jnp.full((8, 8), 1e20, jnp.float32),
         "b": jnp.full((8,), -1e20, jnp.float32)}
    st = init_opt_state(p, cfg)
    p2, st2, m = adamw_update(p, g, st, cfg, pa=pa)
    assert bool(jnp.isfinite(p2["w"]).all())


def test_grad_clip(rng):
    cfg = OptConfig(peak_lr=1e-2, grad_clip=1.0, warmup_steps=1, total_steps=10)
    p = toy_params(rng)
    g = jax.tree.map(lambda x: x * 1e3, toy_grads(rng))
    st = init_opt_state(p, cfg)
    _, _, m = adamw_update(p, g, st, cfg)
    assert float(m["grad_norm"]) > 1.0   # reported pre-clip


def test_bf16_moments(rng):
    cfg = OptConfig(moment_dtype="bfloat16", warmup_steps=1, total_steps=10)
    p = toy_params(rng)
    st = init_opt_state(p, cfg)
    assert st["m"]["w"].dtype == jnp.bfloat16
    p2, st2, _ = adamw_update(p, toy_grads(rng), st, cfg)
    assert st2["v"]["w"].dtype == jnp.bfloat16


def test_schedule():
    cfg = OptConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                    min_lr_ratio=0.1)
    assert float(lr_at(0, cfg)) < 0.2
    np.testing.assert_allclose(float(lr_at(10, cfg)), 1.0, rtol=0.05)
    assert float(lr_at(100, cfg)) <= 0.11
    lin = OptConfig(peak_lr=1.0, warmup_steps=1, total_steps=100,
                    schedule="linear", min_lr_ratio=0.0)
    np.testing.assert_allclose(float(lr_at(50, lin)), 0.5, atol=0.03)
