"""Property-based tests (hypothesis) for the system's core invariants.

Skipped cleanly when ``hypothesis`` is absent (it is a dev-only extra, see
requirements-dev.txt) so a bare interpreter can still run tier-1.
"""
import math

import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import pam_value, padiv_value, paexp2_value, palog2_value
from repro.core import floatbits as fb

# bounds must be exactly float32-representable for width=32 strategies
_LO = float(np.float32(1e-30))
_HI = float(np.float32(1e30))
finite = st.floats(min_value=_LO, max_value=_HI, allow_nan=False,
                   allow_infinity=False, width=32)
signed = st.floats(min_value=-_HI, max_value=_HI, allow_nan=False,
                   allow_infinity=False, width=32).filter(lambda x: abs(x) > _LO)


def f32(x):
    return jnp.asarray(np.float32(x))


@settings(max_examples=300, deadline=None)
@given(a=signed, b=signed)
def test_pam_relative_error_band(a, b):
    """PAM error is always in [-1/9, 0] relative to the true product."""
    p = float(pam_value(f32(a), f32(b)))
    true = float(np.float32(a)) * float(np.float32(b))
    fmax = float(np.finfo(np.float32).max)
    if not np.isfinite(true) or true == 0 or p == 0.0 or abs(true) > fmax:
        return  # over/underflow clamp region: the band only holds in-range
    rel = (p - true) / true
    assert -1 / 9 - 1e-6 <= rel <= 1e-6


@settings(max_examples=300, deadline=None)
@given(a=signed, b=signed)
def test_pam_commutative(a, b):
    assert float(pam_value(f32(a), f32(b))) == float(pam_value(f32(b), f32(a)))


@settings(max_examples=300, deadline=None)
@given(a=signed, b=signed)
def test_pam_sign_correct(a, b):
    p = float(pam_value(f32(a), f32(b)))
    if p != 0.0:
        assert math.copysign(1, p) == math.copysign(1, a) * math.copysign(1, b)


@settings(max_examples=300, deadline=None)
@given(a=signed, k=st.integers(min_value=-30, max_value=30))
def test_pam_by_pow2_exact(a, k):
    """Multiplication by a power of two is exact under PAM (Table 1 relies
    on this for multiplication-free exact derivatives)."""
    b = float(2.0 ** k)
    p = float(pam_value(f32(a), f32(b)))
    true = float(np.float32(np.float32(a) * np.float32(b)))
    if p == 0.0 or not np.isfinite(true):
        return
    assert p == true


@settings(max_examples=300, deadline=None)
@given(a=finite)
def test_log2_exp2_roundtrip(a):
    x = float(paexp2_value(palog2_value(f32(a))))
    # the f32 log-domain value E+M carries |E| into the integer part, losing
    # ~(2+|E|)*2^-24 of mantissa precision -> tolerance scales with |log2 a|
    tol = (4.0 + abs(math.log2(abs(a)))) * 2.0 ** -24
    assert abs(x - float(np.float32(a))) <= tol * abs(a)


@settings(max_examples=300, deadline=None)
@given(a=finite, b=finite)
def test_padiv_inverts_pam(a, b):
    p = float(pam_value(f32(a), f32(b)))
    fmax = float(np.finfo(np.float32).max)
    if p == 0.0 or not np.isfinite(p) or abs(p) >= fmax:
        return  # clamped products are not invertible
    back = float(padiv_value(f32(p), f32(b)))
    assert abs(back - float(np.float32(a))) <= 2e-6 * abs(a)


@settings(max_examples=200, deadline=None)
@given(a=signed, bits=st.integers(min_value=1, max_value=23))
def test_mantissa_round_properties(a, bits):
    r = float(fb.mantissa_round(f32(a), bits))
    # idempotent
    assert float(fb.mantissa_round(f32(r), bits)) == r
    # relative error bounded by half an ulp at `bits`
    if a != 0:
        assert abs(r - float(np.float32(a))) / abs(a) <= 2.0 ** (-bits) + 1e-9


@settings(max_examples=200, deadline=None)
@given(a=signed)
def test_palog2_is_monotone_in_magnitude(a):
    x = abs(float(np.float32(a)))
    l1 = float(palog2_value(f32(x)))
    l2 = float(palog2_value(f32(x * 2)))
    if np.isfinite(l2):
        assert l2 >= l1


@settings(max_examples=200, deadline=None)
@given(data=st.data())
def test_pam_monotone_for_positive(data):
    """For positive fixed b, pam(., b) is non-decreasing (piecewise affine
    with positive slopes)."""
    b = data.draw(finite)
    a1 = data.draw(finite)
    a2 = data.draw(finite)
    lo, hi = sorted([a1, a2])
    p_lo = float(pam_value(f32(lo), f32(b)))
    p_hi = float(pam_value(f32(hi), f32(b)))
    assert p_hi >= p_lo
