"""Property-based tests (hypothesis) for the system's core invariants.

Runs under real ``hypothesis`` when installed (dev-only extra,
requirements-dev.txt); otherwise the seeded fallback driver in
``tests/_proptest.py`` executes the same properties deterministically —
the suite no longer silently skips in the container.
"""
import math

import numpy as np
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # container fallback (seeded)
    from _proptest import given, settings, strategies as st

from repro.core import pam_value, padiv_value, paexp2_value, palog2_value
from repro.core import floatbits as fb

# bounds must be exactly float32-representable for width=32 strategies
_LO = float(np.float32(1e-30))
_HI = float(np.float32(1e30))
finite = st.floats(min_value=_LO, max_value=_HI, allow_nan=False,
                   allow_infinity=False, width=32)
signed = st.floats(min_value=-_HI, max_value=_HI, allow_nan=False,
                   allow_infinity=False, width=32).filter(lambda x: abs(x) > _LO)


def f32(x):
    return jnp.asarray(np.float32(x))


@settings(max_examples=300, deadline=None)
@given(a=signed, b=signed)
def test_pam_relative_error_band(a, b):
    """PAM error is always in [-1/9, 0] relative to the true product."""
    p = float(pam_value(f32(a), f32(b)))
    true = float(np.float32(a)) * float(np.float32(b))
    fmax = float(np.finfo(np.float32).max)
    if not np.isfinite(true) or true == 0 or p == 0.0 or abs(true) > fmax:
        return  # over/underflow clamp region: the band only holds in-range
    rel = (p - true) / true
    assert -1 / 9 - 1e-6 <= rel <= 1e-6


@settings(max_examples=300, deadline=None)
@given(a=signed, b=signed)
def test_pam_commutative(a, b):
    assert float(pam_value(f32(a), f32(b))) == float(pam_value(f32(b), f32(a)))


@settings(max_examples=300, deadline=None)
@given(a=signed, b=signed)
def test_pam_sign_correct(a, b):
    p = float(pam_value(f32(a), f32(b)))
    if p != 0.0:
        assert math.copysign(1, p) == math.copysign(1, a) * math.copysign(1, b)


@settings(max_examples=300, deadline=None)
@given(a=signed, k=st.integers(min_value=-30, max_value=30))
def test_pam_by_pow2_exact(a, k):
    """Multiplication by a power of two is exact under PAM (Table 1 relies
    on this for multiplication-free exact derivatives)."""
    b = float(2.0 ** k)
    p = float(pam_value(f32(a), f32(b)))
    true = float(np.float32(np.float32(a) * np.float32(b)))
    if p == 0.0 or not np.isfinite(true):
        return
    assert p == true


@settings(max_examples=300, deadline=None)
@given(a=finite)
def test_log2_exp2_roundtrip(a):
    x = float(paexp2_value(palog2_value(f32(a))))
    # the f32 log-domain value E+M carries |E| into the integer part, losing
    # ~(2+|E|)*2^-24 of mantissa precision -> tolerance scales with |log2 a|
    tol = (4.0 + abs(math.log2(abs(a)))) * 2.0 ** -24
    assert abs(x - float(np.float32(a))) <= tol * abs(a)


@settings(max_examples=300, deadline=None)
@given(a=finite, b=finite)
def test_padiv_inverts_pam(a, b):
    p = float(pam_value(f32(a), f32(b)))
    fmax = float(np.finfo(np.float32).max)
    if p == 0.0 or not np.isfinite(p) or abs(p) >= fmax:
        return  # clamped products are not invertible
    back = float(padiv_value(f32(p), f32(b)))
    assert abs(back - float(np.float32(a))) <= 2e-6 * abs(a)


@settings(max_examples=200, deadline=None)
@given(a=signed, bits=st.integers(min_value=1, max_value=23))
def test_mantissa_round_properties(a, bits):
    r = float(fb.mantissa_round(f32(a), bits))
    # idempotent
    assert float(fb.mantissa_round(f32(r), bits)) == r
    # relative error bounded by half an ulp at `bits`
    if a != 0:
        assert abs(r - float(np.float32(a))) / abs(a) <= 2.0 ** (-bits) + 1e-9


@settings(max_examples=200, deadline=None)
@given(a=signed)
def test_palog2_is_monotone_in_magnitude(a):
    x = abs(float(np.float32(a)))
    l1 = float(palog2_value(f32(x)))
    l2 = float(palog2_value(f32(x * 2)))
    if np.isfinite(l2):
        assert l2 >= l1


@settings(max_examples=200, deadline=None)
@given(data=st.data())
def test_pam_monotone_for_positive(data):
    """For positive fixed b, pam(., b) is non-decreasing (piecewise affine
    with positive slopes)."""
    b = data.draw(finite)
    a1 = data.draw(finite)
    a2 = data.draw(finite)
    lo, hi = sorted([a1, a2])
    p_lo = float(pam_value(f32(lo), f32(b)))
    p_hi = float(pam_value(f32(hi), f32(b)))
    assert p_hi >= p_lo


# ---------------------------------------------------------------------------
# Flight-recorder tree fingerprint (resilience/recorder.py, DESIGN.md §8).
# ---------------------------------------------------------------------------

import jax  # noqa: E402
from repro.resilience.recorder import (combine_digests, leaf_digest,  # noqa: E402
                                       tree_digest, tree_leaf_digests)

_SHAPES = [(3,), (2, 4), (5,)]


def _tree_from(vals, dtypes):
    """A small {a, b/{c,d}, e} tree over fixed shapes with chosen dtypes."""
    a, c, d = [np.full(s, v, dt)
               for v, dt, s in zip(vals, dtypes, _SHAPES)]
    return {"a": jnp.asarray(a), "b": {"c": jnp.asarray(c),
                                       "d": jnp.asarray(d)}}


_leaf_floats = st.lists(st.floats(min_value=-1e6, max_value=1e6,
                                  allow_nan=False, width=32),
                        min_size=1, max_size=1)


@settings(max_examples=50, deadline=None)
@given(data=st.data())
def test_tree_digest_order_independent(data):
    """The combined digest is a function of {path: leaf bits}, not of dict
    insertion order: rebuilding the same tree with keys inserted in a
    different order must not change it (leaves are salted by PATH crc32)."""
    vals = [data.draw(st.floats(min_value=-1e6, max_value=1e6,
                                allow_nan=False, width=32))
            for _ in range(3)]
    fwd = {"a": jnp.full(_SHAPES[0], np.float32(vals[0])),
           "b": {"c": jnp.full(_SHAPES[1], np.float32(vals[1])),
                 "d": jnp.full(_SHAPES[2], np.float32(vals[2]))}}
    rev = {}
    rev["b"] = {}
    rev["b"]["d"] = jnp.full(_SHAPES[2], np.float32(vals[2]))
    rev["b"]["c"] = jnp.full(_SHAPES[1], np.float32(vals[1]))
    rev["a"] = jnp.full(_SHAPES[0], np.float32(vals[0]))
    assert int(tree_digest(fwd)) == int(tree_digest(rev))
    # and the host-side combine mirrors the in-jit one
    assert int(tree_digest(fwd)) == combine_digests(
        [int(v) for v in np.asarray(tree_leaf_digests(fwd))])


@settings(max_examples=50, deadline=None)
@given(data=st.data())
def test_tree_digest_mixed_dtypes_deterministic(data):
    """f32/bf16 mixed trees digest deterministically (same values+dtypes ->
    same digest; bf16 and f32 encodings of a value differ)."""
    v = data.draw(st.floats(min_value=-1e4, max_value=1e4, allow_nan=False,
                            width=32))
    mixed = {"a": jnp.full(_SHAPES[0], v, jnp.float32),
             "b": {"c": jnp.full(_SHAPES[1], v, jnp.bfloat16),
                   "d": jnp.full(_SHAPES[2], v, jnp.float32)}}
    again = {"a": jnp.full(_SHAPES[0], v, jnp.float32),
             "b": {"c": jnp.full(_SHAPES[1], v, jnp.bfloat16),
                   "d": jnp.full(_SHAPES[2], v, jnp.float32)}}
    assert int(tree_digest(mixed)) == int(tree_digest(again))
    all_f32 = {"a": mixed["a"],
               "b": {"c": mixed["b"]["c"].astype(jnp.float32),
                     "d": mixed["b"]["d"]}}
    if v != 0.0:   # 0.0 has identical (zero) bits in both encodings' words
        assert int(tree_digest(mixed)) != int(tree_digest(all_f32))


@settings(max_examples=50, deadline=None)
@given(data=st.data())
def test_single_bit_flip_changes_digest(data):
    """Acceptance property: ANY single bit flip in ANY leaf changes both
    that leaf's digest and the combined tree digest (fmix32 is a bijection,
    so this is structural, not probabilistic)."""
    vals = [data.draw(st.floats(min_value=-1e6, max_value=1e6,
                                allow_nan=False, width=32))
            for _ in range(3)]
    dtypes = data.draw(st.tuples(*[st.sampled_from([np.float32, "bfloat16"])
                                   for _ in range(3)]))
    import ml_dtypes
    dtypes = [np.dtype(ml_dtypes.bfloat16) if d == "bfloat16" else np.dtype(d)
              for d in dtypes]
    tree = _tree_from(vals, dtypes)
    leaf_i = data.draw(st.integers(min_value=0, max_value=2))
    base = np.asarray(tree_leaf_digests(tree))
    base_combined = int(tree_digest(tree))

    flat, treedef = jax.tree_util.tree_flatten(tree)
    arr = np.array(flat[leaf_i])
    bits = arr.reshape(-1).view(np.uint8)
    bit = data.draw(st.integers(min_value=0, max_value=bits.size * 8 - 1))
    bits[bit // 8] ^= np.uint8(1 << (bit % 8))
    flat[leaf_i] = jnp.asarray(arr)
    flipped = jax.tree_util.tree_unflatten(treedef, flat)

    got = np.asarray(tree_leaf_digests(flipped))
    assert int(got[leaf_i]) != int(base[leaf_i])
    others = [i for i in range(3) if i != leaf_i]
    assert all(int(got[i]) == int(base[i]) for i in others)
    assert int(tree_digest(flipped)) != base_combined


@settings(max_examples=50, deadline=None)
@given(salt=st.integers(min_value=0, max_value=0xFFFFFFFF),
       data=st.data())
def test_leaf_digest_position_sensitive(salt, data):
    """Swapping two distinct elements changes the digest (words are mixed
    with their index before the XOR fold — a plain XOR would be blind to
    transpositions)."""
    a = data.draw(st.floats(min_value=0.5, max_value=1e3, width=32))
    b = data.draw(st.floats(min_value=-1e3, max_value=-0.5, width=32))
    x = jnp.asarray(np.array([a, b, 0.25], np.float32))
    y = jnp.asarray(np.array([b, a, 0.25], np.float32))
    assert int(leaf_digest(x, salt)) != int(leaf_digest(y, salt))
