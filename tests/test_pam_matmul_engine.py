"""Vectorized PAM matmul engine: batched/broadcast paths, Pallas backward
parity, per-product bit-exactness, tunables and the chunked jnp fallback."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import PAConfig, pa_matmul
from repro.core.matmul import (_pam_matmul_value, _exact_grad_a,
                               _exact_grad_b, _swap)
from repro.core.pam import pam_value
from repro.kernels.pam_matmul import (pam_matmul, pam_matmul_ref,
                                      pam_matmul_grads_approx,
                                      pam_exact_grad_a, pam_exact_grad_b,
                                      tile_params)
from repro.kernels import _backend


def bits(x):
    return np.asarray(jax.lax.bitcast_convert_type(x, jnp.int32))


class TestBatchedBroadcast:
    """Parity of the single-launch batched grid vs the jnp path."""

    def test_batched_shared_b(self, rng):
        a = rng.standard_normal((3, 16, 24)).astype(np.float32)
        b = rng.standard_normal((24, 8)).astype(np.float32)
        got = np.asarray(pam_matmul(jnp.asarray(a), jnp.asarray(b),
                                    bm=8, bn=8, bk=8))
        want = np.asarray(_pam_matmul_value(jnp.asarray(a), jnp.asarray(b)))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_batched_both(self, rng):
        a = rng.standard_normal((4, 12, 20)).astype(np.float32)
        b = rng.standard_normal((4, 20, 6)).astype(np.float32)
        got = np.asarray(pam_matmul(jnp.asarray(a), jnp.asarray(b),
                                    bm=8, bn=8, bk=8))
        want = np.asarray(_pam_matmul_value(jnp.asarray(a), jnp.asarray(b)))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_broadcast_a_over_batched_b(self, rng):
        a = rng.standard_normal((12, 20)).astype(np.float32)
        b = rng.standard_normal((3, 20, 6)).astype(np.float32)
        got = np.asarray(pam_matmul(jnp.asarray(a), jnp.asarray(b),
                                    bm=8, bn=8, bk=8))
        want = np.asarray(_pam_matmul_value(jnp.asarray(a), jnp.asarray(b)))
        assert got.shape == (3, 12, 6)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_mixed_broadcast_batch_dims(self, rng):
        a = rng.standard_normal((2, 1, 4, 6)).astype(np.float32)
        b = rng.standard_normal((2, 5, 6, 3)).astype(np.float32)
        got = np.asarray(pam_matmul(jnp.asarray(a), jnp.asarray(b),
                                    bm=4, bn=4, bk=4))
        want = np.asarray(_pam_matmul_value(jnp.asarray(a), jnp.asarray(b)))
        assert got.shape == (2, 5, 4, 3)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_jnp_batched_vs_oracle(self, rng):
        a = rng.standard_normal((2, 3, 9, 17)).astype(np.float32)
        b = rng.standard_normal((17, 7)).astype(np.float32)
        got = np.asarray(_pam_matmul_value(jnp.asarray(a), jnp.asarray(b)))
        for i in range(2):
            for j in range(3):
                want = np.asarray(pam_matmul_ref(a[i, j], b))
                np.testing.assert_allclose(got[i, j], want,
                                           rtol=2e-5, atol=2e-5)


class TestBitExactProducts:
    """K=1 eliminates accumulation: products must be bit-identical to
    pam_value, including zeros, denormal flushes and the clamp band."""

    def _check(self, a, b):
        got = pam_matmul(a, b, bm=64, bn=64, bk=1)
        want = jnp.broadcast_to(pam_value(a, b), got.shape)
        np.testing.assert_array_equal(bits(got), bits(want))
        got_j = _pam_matmul_value(a, b)
        np.testing.assert_array_equal(bits(got_j), bits(want))

    def test_normals_and_zeros(self, rng):
        a = jnp.asarray(rng.standard_normal((32, 1)), jnp.float32)
        a = a.at[3, 0].set(0.0).at[5, 0].set(-0.0)
        b = jnp.asarray(rng.standard_normal((1, 32)), jnp.float32)
        b = b.at[0, 7].set(0.0)
        self._check(a, b)

    def test_underflow_flush(self, rng):
        a = jnp.asarray(rng.standard_normal((16, 1)) * 1e-30, jnp.float32)
        b = jnp.asarray(rng.standard_normal((1, 16)) * 1e-15, jnp.float32)
        self._check(a, b)

    def test_zeros_against_large_magnitudes(self):
        """Regression: PAM(a, 0) must be exactly ±0 for ANY finite a — the
        A-side sentinel alone cannot flush b==0 against |a| >= 2 (raw
        magnitudes), which needs the explicit B-zero mask."""
        big = jnp.float32([[3.4e38], [8.0], [4.0], [-2.0], [1e-38], [0.0]])
        zeros = jnp.float32([[0.0, -0.0, 1.0, -2.0]])
        self._check(big, zeros)
        self._check(jnp.float32([[0.0]]),
                    jnp.float32([[3.4e38, -8.0, 0.0, 1e-40]]))

    def test_zero_cotangent_backward_large_activations(self):
        """Regression: dB = Aᵀ ·̂ g with g == 0 rows and |A| >= 4 must give
        exactly zero gradient columns (routine with masked losses)."""
        a = jnp.float32([[8.0, -16.0], [3.4e38, 4.0]])
        b = jnp.float32([[1.0, 2.0], [3.0, 4.0]])
        for impl in ("jnp", "pallas"):
            pa = PAConfig(mode="matmul", impl=impl, deriv="approx")
            da, db = jax.grad(
                lambda x, y: jnp.sum(pa_matmul(x, y, pa) *
                                     jnp.float32([[0.0, 1.0], [0.0, 1.0]])),
                argnums=(0, 1))(a, b)
            assert np.asarray(db)[:, 0].tolist() == [0.0, 0.0], (impl, db)

    def test_overflow_clamp_band(self):
        # |a*b| in [2^128, 2^129): pam clamps to MAX_FINITE; preserved
        a = jnp.full((4, 1), 2.0**80, jnp.float32)
        b = jnp.full((1, 4), -(2.0**48.5), jnp.float32)
        self._check(a, b)


class TestPallasBackward:
    """Kernel-path backward vs jnp-path backward, both deriv variants."""

    @pytest.mark.parametrize("deriv", ["approx", "exact"])
    def test_grad_parity_2d(self, rng, deriv):
        a = jnp.asarray(rng.standard_normal((6, 33)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((33, 5)), jnp.float32)

        def loss(pa):
            return jax.grad(lambda x, y: jnp.sum(pa_matmul(x, y, pa)),
                            argnums=(0, 1))(a, b)

        da_j, db_j = loss(PAConfig(mode="matmul", impl="jnp", deriv=deriv))
        da_p, db_p = loss(PAConfig(mode="matmul", impl="pallas", deriv=deriv))
        np.testing.assert_allclose(np.asarray(da_p), np.asarray(da_j),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(db_p), np.asarray(db_j),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("deriv", ["approx", "exact"])
    def test_grad_parity_batched(self, rng, deriv):
        a = jnp.asarray(rng.standard_normal((2, 6, 12)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((2, 12, 5)), jnp.float32)

        def loss(pa):
            return jax.grad(lambda x, y: jnp.sum(pa_matmul(x, y, pa)),
                            argnums=(0, 1))(a, b)

        da_j, db_j = loss(PAConfig(mode="matmul", impl="jnp", deriv=deriv))
        da_p, db_p = loss(PAConfig(mode="matmul", impl="pallas", deriv=deriv))
        np.testing.assert_allclose(np.asarray(da_p), np.asarray(da_j),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(db_p), np.asarray(db_j),
                                   rtol=2e-5, atol=2e-5)

    def test_approx_grads_entry_point(self, rng):
        a = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)
        g = jnp.ones((8, 4), jnp.float32)
        da, db = pam_matmul_grads_approx(a, b, g)
        np.testing.assert_allclose(
            np.asarray(da), np.asarray(_pam_matmul_value(g, _swap(b))),
            rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(
            np.asarray(db), np.asarray(_pam_matmul_value(_swap(a), g)),
            rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("impl", ["jnp", "pallas"])
    def test_exact_grads_vs_independent_oracle(self, rng, impl):
        """Both exact-grad engines vs the retained scalar oracle
        (pam_exact_dfactor + pam_value) — catches a bug shared by the two
        fused bit-level implementations, which only cross-check each other
        otherwise."""
        from repro.core.pam import pam_exact_dfactor

        a = jnp.asarray(rng.standard_normal((5, 9)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((9, 4)), jnp.float32)
        b = b.at[:, 1].set(0.0)
        g = jnp.asarray(rng.standard_normal((5, 4)), jnp.float32)
        g = g.at[2, :].set(0.0)

        # oracle: dA[m,k] = sum_n pam(dfactor(a[m,k], b[k,n]), g[m,n])
        f = pam_exact_dfactor(a[:, :, None], b[None, :, :])     # (M, K, N)
        da_oracle = jnp.sum(pam_value(f, g[:, None, :]), axis=-1)
        fb_ = pam_exact_dfactor(b.T[:, :, None], a.T[None, :, :])
        db_oracle = jnp.sum(pam_value(fb_, g.T[:, None, :]), axis=-1).T

        if impl == "pallas":
            da = pam_exact_grad_a(a, b, g, bm=8, bn=8, bk=8)
            db = pam_exact_grad_b(a, b, g, bm=8, bn=8, bk=8)
        else:
            da, db = _exact_grad_a(a, b, g), _exact_grad_b(a, b, g)
        np.testing.assert_allclose(np.asarray(da), np.asarray(da_oracle),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(db), np.asarray(db_oracle),
                                   rtol=2e-5, atol=2e-5)

    def test_1d_left_operand(self, rng):
        """jnp.matmul-style vector @ matrix (regression: the collapse path
        must accept a.ndim == 1)."""
        a = jnp.asarray(rng.standard_normal(8), jnp.float32)
        b = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
        got = pam_matmul(a, b, bm=8, bn=8, bk=8)
        assert got.shape == (4,)
        want = _pam_matmul_value(a[None], b)[0]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_exact_grad_kernel_vs_jnp_with_zeros(self, rng):
        a = jnp.asarray(rng.standard_normal((6, 33)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((33, 5)), jnp.float32)
        b = b.at[:, 2].set(0.0)
        g = jnp.asarray(rng.standard_normal((6, 5)), jnp.float32)
        g = g.at[0, :].set(0.0)
        da = pam_exact_grad_a(a, b, g, bm=8, bn=8, bk=8)
        np.testing.assert_allclose(np.asarray(da),
                                   np.asarray(_exact_grad_a(a, b, g)),
                                   rtol=2e-5, atol=2e-5)
        db = pam_exact_grad_b(a, b, g, bm=8, bn=8, bk=8)
        np.testing.assert_allclose(np.asarray(db),
                                   np.asarray(_exact_grad_b(a, b, g)),
                                   rtol=2e-5, atol=2e-5)


class TestTunablesAndFallback:
    def test_autotune_table_resolves(self):
        bm, bn, bk, g = tile_params(256, 256, 256, True)
        assert bk % g == 0 and bm > 0 and bn > 0

    def test_prime_tile_sizes(self, rng):
        # bk=7 forces the g-divisor adjustment (7 is prime)
        a = rng.standard_normal((5, 7)).astype(np.float32)
        b = rng.standard_normal((7, 3)).astype(np.float32)
        got = np.asarray(pam_matmul(jnp.asarray(a), jnp.asarray(b),
                                    bm=8, bn=8, bk=7, g=16))
        want = np.asarray(pam_matmul_ref(a, b))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_chunked_scan_matches_single_shot(self, rng):
        a = jnp.asarray(rng.standard_normal((8, 640)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((640, 4)), jnp.float32)
        single = _pam_matmul_value(a, b, budget=1 << 24)
        chunked = _pam_matmul_value(a, b, budget=64)
        # identical group-level products; only the scan carries differ
        np.testing.assert_allclose(np.asarray(chunked), np.asarray(single),
                                   rtol=2e-5, atol=2e-5)

    @pytest.mark.slow
    def test_reference_shape_parity(self, rng):
        """The benchmark's 256^3 reference shape, autotuned tiles."""
        a = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
        got = np.asarray(pam_matmul(a, b))
        want = np.asarray(_pam_matmul_value(a, b))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    @pytest.mark.slow
    def test_large_batched_grid(self, rng):
        a = jnp.asarray(rng.standard_normal((4, 128, 128)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((4, 128, 128)), jnp.float32)
        got = np.asarray(pam_matmul(a, b))
        want = np.asarray(_pam_matmul_value(a, b))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_interpret_backend_helper(self, monkeypatch):
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
        assert _backend.use_interpret() is True
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
        assert _backend.use_interpret() is False
        monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
        assert _backend.use_interpret() == (jax.default_backend() != "tpu")
