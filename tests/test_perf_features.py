"""§Perf levers must be semantics-preserving: each optimized path is checked
against its baseline counterpart (these guards backed the hillclimb)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config, get_optimized_config, ARCHS
from repro.models import build_model
from repro.models.moe import moe_ffn


def _toks(cfg, rng, b=2, s=16):
    return jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)


@pytest.mark.parametrize("dispatch", ["gather", "hybrid"])
def test_dispatch_modes_bitexact(rng, dispatch):
    cfg = get_smoke_config("kimi-k2-1t-a32b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda x: x[0], params["layers"])
    h = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    o1, a1 = moe_ffn(h, lp["moe"], cfg)
    cfg2 = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch=dispatch))
    o2, a2 = moe_ffn(h, lp["moe"], cfg2)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    assert float(a1) == float(a2)
    g1 = jax.grad(lambda p: jnp.sum(moe_ffn(h, p, cfg)[0]))(lp["moe"])
    g2 = jax.grad(lambda p: jnp.sum(moe_ffn(h, p, cfg2)[0]))(lp["moe"])
    for x, y in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_fused_ssm_scan_bitexact(rng):
    cfg = get_smoke_config("hymba-1.5b")
    m1 = build_model(cfg)
    m2 = build_model(cfg.replace(ssm_fused_scan=True))
    params = m1.init(jax.random.PRNGKey(0))
    toks = _toks(cfg, rng)
    l1, _ = m1.logits(params, {"tokens": toks})
    l2, _ = m2.logits(params, {"tokens": toks})
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_chunked_ssm_scan_grad_equivalent(rng):
    cfg = get_smoke_config("hymba-1.5b")
    m1 = build_model(cfg.replace(ssm_fused_scan=True))
    m2 = build_model(cfg.replace(ssm_fused_scan=True, ssm_time_chunk=4))
    params = m1.init(jax.random.PRNGKey(0))
    batch = {"tokens": _toks(cfg, rng), "labels": _toks(cfg, rng)}
    l1, g1 = jax.value_and_grad(m1.loss)(params, batch)
    l2, g2 = jax.value_and_grad(m2.loss)(params, batch)
    # remat recompute may reorder f32 reductions -> tiny numeric noise
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-5)


def test_banded_swa_equals_masked_swa(rng):
    cfg = get_smoke_config("h2o-danube-3-4b").replace(max_seq_len=128)
    m1 = build_model(cfg)
    m2 = build_model(cfg.replace(attn_local_banded=True))
    params = m1.init(jax.random.PRNGKey(0))
    toks = _toks(cfg, rng, s=96)   # 3 blocks of window=32
    l1, _ = m1.logits(params, {"tokens": toks})
    l2, _ = m2.logits(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_scale_in_q_equivalent(rng):
    cfg = get_smoke_config("llama3.2-1b")
    m1 = build_model(cfg)
    m2 = build_model(cfg.replace(attn_scale_in_q=True))
    params = m1.init(jax.random.PRNGKey(0))
    toks = _toks(cfg, rng)
    l1, _ = m1.logits(params, {"tokens": toks})
    l2, _ = m2.logits(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", ["hymba-1.5b", "kimi-k2-1t-a32b",
                                  "h2o-danube-3-4b", "smollm-135m"])
def test_optimized_profile_still_trains(arch, rng):
    """get_optimized_config must produce a working model per arch."""
    from repro.configs.base import reduce_for_smoke
    cfg = reduce_for_smoke(get_optimized_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": _toks(cfg, rng), "labels": _toks(cfg, rng)}
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    for g in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(g.astype(jnp.float32)).all())
