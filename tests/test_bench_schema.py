"""The bench-schema checker: committed trajectory files must validate, and
the checker must actually reject malformed ones (it gates `make test`)."""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.check_bench_schema import (bench_files, validate_file,
                                           validate_report)


def test_committed_trajectory_files_valid():
    files = bench_files()
    assert files, "no BENCH_*.json at repo root — trajectory lost"
    for path in files:
        assert validate_file(path) == [], validate_file(path)


def test_rejects_missing_ratio_fields():
    bad = {"benchmark": "x", "schema_version": 1, "generated_utc": "t",
           "backend": "cpu", "pallas_mode": "interpret",
           "timing": {"rounds": 1, "stat": "min", "unit": "us"},
           "forward_us": {"a": 1.0}}
    errs = validate_report(bad, "BENCH_x.json")
    assert any("_speedup_vs_seed" in e for e in errs)
    assert any("slowdown_vs_native" in e for e in errs)


def test_rejects_wrong_schema_version_and_name(tmp_path):
    bad = {"benchmark": "y", "schema_version": 2, "generated_utc": "t",
           "backend": "cpu", "pallas_mode": "interpret",
           "timing": {"stat": "min", "unit": "us"},
           "forward_us": {"a": 1.0},
           "forward_speedup_vs_seed": {"a": 1.0},
           "slowdown_vs_native": {"a": 1.0}}
    p = tmp_path / "BENCH_x.json"
    p.write_text(json.dumps(bad))
    errs = validate_file(str(p))
    assert any("schema_version" in e for e in errs)
    assert any("rounds" in e for e in errs)
    assert any("does not match filename" in e for e in errs)


def test_rejects_unreadable(tmp_path):
    p = tmp_path / "BENCH_z.json"
    p.write_text("{not json")
    assert any("unreadable" in e for e in validate_file(str(p)))


def test_rejects_non_numeric_us(tmp_path):
    bad = {"benchmark": "z", "schema_version": 1, "generated_utc": "t",
           "backend": "cpu", "pallas_mode": "interpret",
           "timing": {"rounds": 1, "stat": "min", "unit": "us"},
           "forward_us": {"a": "fast"},
           "forward_speedup_vs_seed": {"a": 1.0},
           "slowdown_vs_native": {"a": 1.0}}
    errs = validate_report(bad, "BENCH_z.json")
    assert any("forward_us" in e for e in errs)
