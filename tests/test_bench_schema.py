"""The bench-schema checker: committed trajectory files must validate, and
the checker must actually reject malformed ones (it gates `make test`)."""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.check_bench_schema import (bench_files, validate_file,
                                           validate_report)


def test_committed_trajectory_files_valid():
    files = bench_files()
    assert files, "no BENCH_*.json at repo root — trajectory lost"
    for path in files:
        assert validate_file(path) == [], validate_file(path)


def test_rejects_missing_ratio_fields():
    bad = {"benchmark": "x", "schema_version": 1, "generated_utc": "t",
           "backend": "cpu", "pallas_mode": "interpret",
           "timing": {"rounds": 1, "stat": "min", "unit": "us"},
           "forward_us": {"a": 1.0}}
    errs = validate_report(bad, "BENCH_x.json")
    assert any("_speedup_vs_seed" in e for e in errs)
    assert any("slowdown_vs_native" in e for e in errs)


def test_rejects_wrong_schema_version_and_name(tmp_path):
    bad = {"benchmark": "y", "schema_version": 2, "generated_utc": "t",
           "backend": "cpu", "pallas_mode": "interpret",
           "timing": {"stat": "min", "unit": "us"},
           "forward_us": {"a": 1.0},
           "forward_speedup_vs_seed": {"a": 1.0},
           "slowdown_vs_native": {"a": 1.0}}
    p = tmp_path / "BENCH_x.json"
    p.write_text(json.dumps(bad))
    errs = validate_file(str(p))
    assert any("schema_version" in e for e in errs)
    assert any("rounds" in e for e in errs)
    assert any("does not match filename" in e for e in errs)


def test_rejects_unreadable(tmp_path):
    p = tmp_path / "BENCH_z.json"
    p.write_text("{not json")
    assert any("unreadable" in e for e in validate_file(str(p)))


def _mini_formats(size_key="operand_bytes"):
    """Minimal valid per-FloatFormat section (DESIGN.md §11)."""
    def sec(f32):
        return {"engines": {"jnp": 1.0, "pallas": 2.0},
                size_key: 4096 if f32 else 2048,
                "hbm_bytes_accessed": 1000 if f32 else 600,
                "energy": {"engines": {"pam": {"win_vs_native": 4.6}}}}
    return {"f32": sec(True), "bf16": sec(False)}


def test_format_sections_gates():
    """The bf16 row must halve the operand/state bytes everywhere; the
    measured HBM reduction is gated on the matmul bench only (the CPU jnp
    streaming engines pay f32-accumulation cast traffic the schema does
    not hold against attention/optim)."""
    from benchmarks.check_bench_schema import _validate_formats
    rep = {"formats": _mini_formats()}
    assert _validate_formats(rep, "BENCH_pam_attention.json") == []
    assert _validate_formats(rep, "BENCH_pam_matmul.json") == []

    swollen = _mini_formats()
    swollen["bf16"]["hbm_bytes_accessed"] = 2000
    assert _validate_formats({"formats": swollen},
                             "BENCH_pam_attention.json") == []
    errs = _validate_formats({"formats": swollen}, "BENCH_pam_matmul.json")
    assert any("not reduced" in e for e in errs)

    fat = _mini_formats()
    fat["bf16"]["operand_bytes"] = fat["f32"]["operand_bytes"]
    errs = _validate_formats({"formats": fat}, "BENCH_pam_attention.json")
    assert any("operand_bytes" in e for e in errs)

    noenergy = _mini_formats()
    del noenergy["bf16"]["energy"]
    errs = _validate_formats({"formats": noenergy}, "BENCH_pam_matmul.json")
    assert any("energy" in e for e in errs)


def test_attention_requires_v2_backward_fields():
    """BENCH_pam_attention.json is schema v2: backward-engine provenance,
    the vs-unfused-live backward ratio, GQA KV accounting and the kernel
    fingerprint are all mandatory."""
    base = {"benchmark": "pam_attention", "schema_version": 1,
            "generated_utc": "t", "backend": "cpu",
            "pallas_mode": "interpret",
            "timing": {"rounds": 1, "stat": "min", "unit": "us"},
            "forward_us": {"a": 1.0}, "fwd_bwd_us": {"a": 1.0},
            "forward_speedup_vs_seed": {"a": 1.0},
            "slowdown_vs_native": {"a": 1.0}}
    errs = validate_report(base, "BENCH_pam_attention.json")
    assert any("schema_version must be 3" in e for e in errs)
    base["schema_version"] = 3
    errs = validate_report(base, "BENCH_pam_attention.json")
    assert any("backward" in e for e in errs)
    assert any("fwd_bwd_speedup_vs_unfused_live" in e for e in errs)
    assert any("gqa" in e for e in errs)
    assert any("flash_attention_fingerprint" in e for e in errs)
    assert any("'formats' section" in e for e in errs)
    base.update({
        "backward": {"engine": "two_sweep_recompute", "sweeps": 2},
        "fwd_bwd_speedup_vs_unfused_live": {"a": 1.0},
        "gqa": {"kv_bytes_fused": 1, "kv_bytes_repeat": 2,
                "kv_repeat_free": True},
        "flash_attention_fingerprint": "abc",
        "formats": _mini_formats(),
    })
    assert validate_report(base, "BENCH_pam_attention.json") == []


def test_rejects_stale_attention_fingerprint(tmp_path):
    """A committed attention trajectory point generated from OLD kernel
    sources must fail validation — flash_attention/ changes force a bench
    re-run."""
    import benchmarks.check_bench_schema as cbs
    with open(os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_pam_attention.json")) as f:
        report = json.load(f)
    report["flash_attention_fingerprint"] = "0" * 16
    p = tmp_path / "BENCH_pam_attention.json"
    p.write_text(json.dumps(report))
    errs = cbs.validate_file(str(p))
    assert any("stale" in e for e in errs)
    # (the committed file's own freshness is covered by
    # test_committed_trajectory_files_valid — validate_file recomputes the
    # digest of src/repro/kernels/flash_attention/*.py on every run)


def test_pam_optim_requires_fingerprint_gates_and_audit():
    """BENCH_pam_optim.json must carry the fused-kernel source fingerprint,
    a non-empty gate record, the vs-seed ratio and a clean multiplication
    audit — a leaky or unverified optimizer can't commit a trajectory
    point."""
    base = {"benchmark": "pam_optim", "schema_version": 1,
            "generated_utc": "t", "backend": "cpu",
            "pallas_mode": "interpret",
            "timing": {"rounds": 1, "stat": "min", "unit": "us"},
            "update_us": {"a": 1.0},
            "forward_speedup_vs_seed": {"a": 1.0},
            "slowdown_vs_native": {"a": 1.0}}
    errs = validate_report(base, "BENCH_pam_optim.json")
    assert any("pam_optim_fingerprint" in e for e in errs)
    assert any("gates_passed" in e for e in errs)
    assert any("update_speedup_vs_seed" in e for e in errs)
    assert any("multiplication_audit" in e for e in errs)
    base.update({
        "pam_optim_fingerprint": "abc",
        "gates_passed": ["bit_parity_f32_vs_seed"],
        "update_speedup_vs_seed": {"fused_jnp": 1.0},
        "multiplication_audit": {"tensor_total": 1},
    })
    errs = validate_report(base, "BENCH_pam_optim.json")
    assert any("tensor_total must be 0" in e for e in errs)
    base["multiplication_audit"] = {"tensor_total": 0}
    base["schema_version"] = 2
    base["formats"] = _mini_formats(size_key="state_bytes")
    assert validate_report(base, "BENCH_pam_optim.json") == []


def test_rejects_stale_pam_optim_fingerprint(tmp_path):
    """Editing kernels/pam_optim/ without re-running the bench must fail
    validation of the committed trajectory point."""
    import benchmarks.check_bench_schema as cbs
    with open(os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_pam_optim.json")) as f:
        report = json.load(f)
    report["pam_optim_fingerprint"] = "0" * 16
    p = tmp_path / "BENCH_pam_optim.json"
    p.write_text(json.dumps(report))
    errs = cbs.validate_file(str(p))
    assert any("stale" in e for e in errs)


@pytest.mark.slow
def test_smoke_optim_bench_runs_gates_and_validates(tmp_path):
    """`make bench-fast` optimizer entry: the bench at smoke shapes must run
    its bit-parity + audit gates and produce a structurally complete report
    (thrown-away output path; the tracked trajectory point is untouched)."""
    from benchmarks import pam_optim_bench
    out = tmp_path / "BENCH_optim_smoke.json"
    pam_optim_bench.main(["--smoke", "--out", str(out)])
    report = json.loads(out.read_text())
    assert report["multiplication_audit"]["tensor_total"] == 0
    assert "bit_parity_f32_vs_seed" in report["gates_passed"]
    assert "update_jaxpr_mult_free_pallas" in report["gates_passed"]


def test_rejects_non_numeric_us(tmp_path):
    bad = {"benchmark": "z", "schema_version": 1, "generated_utc": "t",
           "backend": "cpu", "pallas_mode": "interpret",
           "timing": {"rounds": 1, "stat": "min", "unit": "us"},
           "forward_us": {"a": "fast"},
           "forward_speedup_vs_seed": {"a": 1.0},
           "slowdown_vs_native": {"a": 1.0}}
    errs = validate_report(bad, "BENCH_z.json")
    assert any("forward_us" in e for e in errs)


@pytest.mark.slow
def test_smoke_bench_runs_gates_and_validates(tmp_path):
    """`make bench-fast` path: the attention bench at smoke shapes must run
    its correctness gates and produce a structurally v2-complete report
    (written to a throwaway path, never the tracked trajectory point)."""
    from benchmarks import pam_attention_bench
    out = tmp_path / "BENCH_smoke.json"
    pam_attention_bench.main(["--smoke", "--out", str(out)])
    report = json.loads(out.read_text())
    assert report["backward"]["sweeps"] == 2
    assert report["gqa"]["kv_repeat_free"] is True
    assert report["gates_passed"], "no gates ran"


def test_bench_gates_exit_nonzero_on_failure(capsys):
    """A tripped correctness gate must abort the bench with a nonzero exit
    (no JSON gets written) — a regressed kernel can't leave a green file."""
    from benchmarks.pam_attention_bench import _Gates

    def boom():
        raise AssertionError("kernel regressed")

    g = _Gates()
    g.run("ok", lambda: None)
    g.run("boom", boom)
    with pytest.raises(SystemExit) as e:
        g.finish()
    assert e.value.code == 2
    assert "boom" in capsys.readouterr().err


def test_serve_requires_fingerprint_parity_gate_and_audit():
    """BENCH_serve.json must carry the serve/ source fingerprint, a gate
    record that includes token parity, the throughput-vs-seed ratio,
    slot-occupancy telemetry, and a clean decode-step multiplication
    audit — a throughput win without output parity (or with a leaky
    decode step) can't commit a trajectory point. Serve is schema_version 2
    since the flight recorder landed: a run-twice ``determinism`` section
    with identical request digests is also mandatory."""
    base = {"benchmark": "serve", "schema_version": 2,
            "generated_utc": "t", "backend": "cpu",
            "pallas_mode": "n/a",
            "timing": {"rounds": 1, "stat": "min", "unit": "us"},
            "engine_us": {"a": 1.0},
            "forward_speedup_vs_seed": {"a": 1.0},
            "slowdown_vs_native": {"a": 1.0}}
    errs = validate_report(base, "BENCH_serve.json")
    assert any("serve_fingerprint" in e for e in errs)
    assert any("gates_passed" in e for e in errs)
    assert any("throughput_speedup_vs_seed" in e for e in errs)
    assert any("slot_occupancy" in e for e in errs)
    assert any("'recovery'" in e for e in errs)
    assert any("multiplication_audit" in e for e in errs)
    assert any("determinism" in e for e in errs)
    base.update({
        "serve_fingerprint": "abc",
        "gates_passed": ["throughput_vs_seed"],
        "throughput_speedup_vs_seed": {"tokens_per_s": 2.0},
        "slot_occupancy": {"mean": 0.8},
        "recovery": {"evicted_nonfinite": 1.0, "recovered_slots": 1.0},
        "multiplication_audit": {"tensor_total": 1},
        "determinism": {"runs": 2, "requests": 12, "identical": False,
                        "digest_fold": "0xdeadbeef"},
    })
    errs = validate_report(base, "BENCH_serve.json")
    assert any("token-parity" in e for e in errs)
    assert any("tensor_total must be 0" in e for e in errs)
    assert any("identical" in e for e in errs)
    base["gates_passed"] = ["token_parity_continuous_vs_oneshot"]
    base["multiplication_audit"] = {"tensor_total": 0}
    base["determinism"]["identical"] = True
    assert validate_report(base, "BENCH_serve.json") == []
    # a pre-recorder v1 report is rejected outright: no silent downgrades
    v1 = dict(base, schema_version=1)
    del v1["determinism"]
    assert any("schema_version" in e
               for e in validate_report(v1, "BENCH_serve.json"))


def test_rejects_stale_serve_fingerprint(tmp_path):
    """Editing src/repro/serve/ without re-running the bench must fail
    validation of the committed trajectory point."""
    import benchmarks.check_bench_schema as cbs
    with open(os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_serve.json")) as f:
        report = json.load(f)
    report["serve_fingerprint"] = "0" * 16
    p = tmp_path / "BENCH_serve.json"
    p.write_text(json.dumps(report))
    errs = cbs.validate_file(str(p))
    assert any("stale" in e for e in errs)


@pytest.mark.slow
def test_smoke_serve_bench_runs_gates_and_validates(tmp_path):
    """`make bench-fast` serving entry: the bench on a small trace must run
    its parity + throughput + audit gates and produce a structurally
    complete report (thrown-away output path; the tracked trajectory point
    is untouched)."""
    from benchmarks import serve_bench
    out = tmp_path / "BENCH_serve_smoke.json"
    serve_bench.main(["--smoke", "--out", str(out)])
    report = json.loads(out.read_text())
    assert report["multiplication_audit"]["tensor_total"] == 0
    assert "token_parity_continuous_vs_oneshot" in report["gates_passed"]
    assert "token_parity_full_pa" in report["gates_passed"]
    assert "throughput_vs_seed" in report["gates_passed"]
    assert report["throughput_speedup_vs_seed"]["tokens_per_s"] > 1.0
