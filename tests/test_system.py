"""End-to-end behaviour tests for the paper's system."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import PAConfig
from repro.configs import get_smoke_config
from repro.models import build_model
from repro.optim import OptConfig, init_opt_state
from repro.data import DataConfig, SyntheticLM
from repro.train import TrainConfig, make_train_step


def test_fully_multiplication_free_training_step():
    """Paper headline: forward + backward + optimizer all in PA ops.

    We verify the compiled HLO of a PA-full train step contains no
    multiply on float operands outside trig constants: every float multiply
    must come from power-of-two scaling (exact) or trace-time constants.
    Practical proxy: the step runs, loss is finite, and a few steps reduce
    the loss on structured data.
    """
    cfg = get_smoke_config("smollm-135m",
                           pa=PAConfig(mode="full", deriv="approx",
                                       loss_deriv="exact"))
    cfg = cfg.replace(param_dtype="float32", compute_dtype="float32",
                      vocab_size=64)
    model = build_model(cfg)
    opt = OptConfig(peak_lr=3e-3, warmup_steps=2, total_steps=12)
    data = SyntheticLM(DataConfig(vocab_size=64, seq_len=16, global_batch=4))
    step = jax.jit(make_train_step(model, opt))
    params = model.init(jax.random.PRNGKey(0))
    st = init_opt_state(params, opt)
    losses = []
    for i in range(12):
        b = jax.tree.map(jnp.asarray, data.batch(i))
        params, st, m = step(params, st, b)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


def test_pa_and_baseline_share_hyperparameters():
    """The paper's drop-in property: identical config except the PA flag."""
    base = get_smoke_config("smollm-135m").replace(
        param_dtype="float32", compute_dtype="float32", vocab_size=64)
    pa = base.replace(pa=PAConfig(mode="matmul"))
    opt = OptConfig(peak_lr=3e-3, warmup_steps=2, total_steps=10)
    data = SyntheticLM(DataConfig(vocab_size=64, seq_len=16, global_batch=4))

    final = {}
    for name, cfg in (("base", base), ("pa", pa)):
        model = build_model(cfg)
        step = jax.jit(make_train_step(model, opt))
        params = model.init(jax.random.PRNGKey(0))
        st = init_opt_state(params, opt)
        for i in range(10):
            b = jax.tree.map(jnp.asarray, data.batch(i))
            params, st, m = step(params, st, b)
        final[name] = float(m["loss"])
    # PA tracks the baseline (generous tolerance at 10 steps)
    assert abs(final["pa"] - final["base"]) < 0.5


def test_pallas_impl_matches_jnp_impl_forward():
    """pallas and jnp backends are bit-compatible per product (accumulation
    order may differ)."""
    cfg_j = get_smoke_config("smollm-135m", pa=PAConfig(mode="matmul", impl="jnp"))
    cfg_p = get_smoke_config("smollm-135m", pa=PAConfig(mode="matmul", impl="pallas"))
    cfg_j = cfg_j.replace(n_layers=1, param_dtype="float32", compute_dtype="float32")
    cfg_p = cfg_p.replace(n_layers=1, param_dtype="float32", compute_dtype="float32")
    mj, mp = build_model(cfg_j), build_model(cfg_p)
    params = mj.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 64, (1, 8)), jnp.int32)
    lj, _ = mj.logits(params, {"tokens": toks})
    lp, _ = mp.logits(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lj), np.asarray(lp),
                               rtol=1e-4, atol=1e-4)
